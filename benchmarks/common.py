"""Shared benchmark helpers: datasets, timing, CSV rows.

All benchmarks run on XLA:CPU at reduced scale (this container), with the
same code paths the TPU target uses (kernels dispatch per
repro.kernels.ops.get_backend()).  Construction time is wall-clock of the
jitted build, recall measured with the unified search (paper Fig 5/6
protocol: same search algorithm for every index).
"""
from __future__ import annotations

import contextlib
import time
import zlib

import jax

from repro.core import grnnd, recall as R
from repro.core.search import search
from repro.data import synthetic
from repro.kernels import ops

K = 10
EF = 48

# interpret mode steps the kernel grid from Python: benchmarks cap their
# dataset so a full run stays tractable (parity with the fast path is
# separately asserted by the test tier)
INTERPRET_MAX_N = 512


def backend_scope(backend: str | None):
    """Fresh scoped override of the kernel backend; no-op for None."""
    return contextlib.nullcontext() if backend is None else ops.backend(backend)


def resolve_backend(backend: str | None) -> tuple[str, str]:
    """Map a --backend flag to (effective backend, row-name tag).

    The effective backend is what will actually execute ("pallas" degrades
    to "interpret" off-TPU); the tag is the `-<effective>` row-name suffix
    the fig benchmarks append.  The ambient selection (no flag) stays
    untagged EXCEPT when it resolves to interpret: interpret runs shrink
    the benchmark scale, and rows from a shrunken run must never share a
    name with full-scale rows (cross-run comparability, same class of bug
    as the bench_datasets seeding fix).
    """
    with backend_scope(backend):
        eff = ops.effective_backend()
    return eff, f"-{eff}" if (backend is not None or eff == "interpret") else ""


def bench_datasets(n: int = 6000, nq: int = 300):
    """Reduced-scale stand-ins for SIFT1M/DEEP1M/GIST1M."""
    out = {}
    for name, preset in (("sift-like", "sift-like"),
                         ("deep-like", "deep-like"),
                         ("gist-like", "gist-like")):
        # gist floor never exceeds the caller's n: interpret-mode callers
        # clamp n to INTERPRET_MAX_N, and the floor must not bypass that
        nn = n if preset != "gist-like" else min(max(n // 2, 1000), n)
        # crc32, not hash(): str hashing is salted per process, which made
        # every benchmark invocation draw a DIFFERENT dataset — rows from
        # separate runs (e.g. dense vs hashed search) were incomparable
        seed = zlib.crc32(name.encode()) % 2**31
        x = synthetic.make_preset(jax.random.PRNGKey(seed), preset, nn)
        q = synthetic.queries_from(jax.random.PRNGKey(7), x, nq)
        gt = R.brute_force_knn(x, q, K)
        out[name] = (x, q, gt)
    return out


def timed_build(x, cfg: grnnd.GRNNDConfig, key=None, repeats: int = 1):
    """Compile-excluded wall time of the jitted GRNND build."""
    key = key if key is not None else jax.random.PRNGKey(1)
    pool = grnnd.build_graph(key, x, cfg)          # compile + warm
    pool.ids.block_until_ready()
    times = []
    for i in range(repeats):
        t0 = time.perf_counter()
        pool = grnnd.build_graph(jax.random.fold_in(key, i), x, cfg)
        pool.ids.block_until_ready()
        times.append(time.perf_counter() - t0)
    return pool, min(times)


def eval_recall(x, graph_ids, q, gt, ef: int = EF):
    res = search(x, graph_ids, q, k=K, ef=ef)
    return R.recall_at_k(res.ids, gt)


def timed_search(x, graph_ids, q, ef: int = EF, repeats: int = 3,
                 backend: str | None = None, visited: str = "dense",
                 visited_cap: int | None = None, rescore=None,
                 labels=None, filter=None, entry=None, ids_map=None):
    """Compile-excluded search wall time -> (result, QPS).

    `backend`/`visited`/`visited_cap` select the query-path configuration
    (kernels/search_expand.py + hashed visited set); defaults reproduce the
    ambient-backend dense-bitmask search.  `x` may be a VectorStore and
    `rescore` the fp32 tier (the precision ladder, DESIGN.md §8);
    `labels`/`filter` the filtered-search predicate (DESIGN.md §9);
    `entry`/`ids_map` the optimized layout's mapped entry point and
    inverse permutation (core/layout.py, DESIGN.md §10).
    """
    kw = dict(k=K, ef=ef, visited=visited, visited_cap=visited_cap,
              rescore=rescore, labels=labels, filter=filter,
              entry=entry, ids_map=ids_map)
    with backend_scope(backend):
        res = search(x, graph_ids, q, **kw)        # compile + warm
        res.ids.block_until_ready()
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = search(x, graph_ids, q, **kw)
            res.ids.block_until_ready()
            times.append(time.perf_counter() - t0)
    qps = q.shape[0] / min(times)
    return res, qps


def row(name: str, seconds: float, derived: str, *,
        precision: str = "fp32", bytes_per_vector: float = 0.0,
        opt_layout: str | None = None) -> str:
    """One harness CSV row.

    Every row carries the traversal-tier `precision=` and `bpv=` (bytes
    per stored vector; 0.0 where no vector storage is involved, e.g.
    analytic cells) so the perf trajectory can distinguish dtype
    regressions from algorithmic ones — benchmarks/run.py validates both
    fields on the smoke artifact (SMOKE_SCHEMA 2).  `opt_layout` is the
    graph-layout tag (SMOKE_SCHEMA 4, core/layout.py): "none" for the raw
    pool layout, or the ordering (+ pruned degree) of an optimized index —
    required on every fig6 row so the QPS trajectory never silently mixes
    layouts.
    """
    opt = "" if opt_layout is None else f" opt_layout={opt_layout}"
    return (f"{name},{seconds * 1e6:.1f},{derived}"
            f" precision={precision} bpv={bytes_per_vector:.1f}{opt}")


def fp32_bpv(x) -> float:
    """Traversal-tier bytes/vector of a plain fp32 dataset."""
    return 4.0 * x.shape[1]
