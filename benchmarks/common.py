"""Shared benchmark helpers: datasets, timing, CSV rows.

All benchmarks run on XLA:CPU at reduced scale (this container), with the
same code paths the TPU target uses (kernels dispatch per
repro.kernels.ops.get_backend()).  Construction time is wall-clock of the
jitted build, recall measured with the unified search (paper Fig 5/6
protocol: same search algorithm for every index).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grnnd, recall as R
from repro.core.search import search
from repro.data import synthetic

K = 10
EF = 48


def bench_datasets(n: int = 6000, nq: int = 300):
    """Reduced-scale stand-ins for SIFT1M/DEEP1M/GIST1M."""
    out = {}
    for name, preset in (("sift-like", "sift-like"),
                         ("deep-like", "deep-like"),
                         ("gist-like", "gist-like")):
        nn = n if preset != "gist-like" else max(n // 2, 1000)
        x = synthetic.make_preset(jax.random.PRNGKey(hash(name) % 2**31),
                                  preset, nn)
        q = synthetic.queries_from(jax.random.PRNGKey(7), x, nq)
        gt = R.brute_force_knn(x, q, K)
        out[name] = (x, q, gt)
    return out


def timed_build(x, cfg: grnnd.GRNNDConfig, key=None, repeats: int = 1):
    """Compile-excluded wall time of the jitted GRNND build."""
    key = key if key is not None else jax.random.PRNGKey(1)
    pool = grnnd.build_graph(key, x, cfg)          # compile + warm
    pool.ids.block_until_ready()
    times = []
    for i in range(repeats):
        t0 = time.perf_counter()
        pool = grnnd.build_graph(jax.random.fold_in(key, i), x, cfg)
        pool.ids.block_until_ready()
        times.append(time.perf_counter() - t0)
    return pool, min(times)


def eval_recall(x, graph_ids, q, gt, ef: int = EF):
    res = search(x, graph_ids, q, k=K, ef=ef)
    return R.recall_at_k(res.ids, gt)


def timed_search(x, graph_ids, q, ef: int = EF, repeats: int = 3):
    res = search(x, graph_ids, q, k=K, ef=ef)      # compile + warm
    res.ids.block_until_ready()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = search(x, graph_ids, q, k=K, ef=ef)
        res.ids.block_until_ready()
        times.append(time.perf_counter() - t0)
    qps = q.shape[0] / min(times)
    return res, qps


def row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
