"""Fig 10 (beyond the paper): the dynamic index under corpus churn.

The paper's evaluation — like RNN-Descent's and CAGRA's — stops at static
construction + query; any corpus change forces a full rebuild.  This
benchmark measures what `core.dynamic.DynamicIndex` buys instead:

  * **insert throughput** — vectors/s of batched online insertion (seed
    search + symmetric staging + localized refinement rounds);
  * **recall under churn** — recall@10 after inserting 10% new vectors,
    against a from-scratch rebuild on the same final corpus (the ISSUE 3
    acceptance bound: within 2 recall points at < 25% of the rebuild's
    propagation-round count);
  * **delete + compact** — recall against LIVE-corpus ground truth after
    tombstoning 10%, and the exact search-preservation of `compact()`.

Rows are `fig10/<dataset>/<metric>` CSV in the shared harness format.

    PYTHONPATH=src python benchmarks/fig10_churn.py [--backend ref] [--n 2000]
"""
from __future__ import annotations

import argparse
import time

if __package__ in (None, ""):  # direct `python benchmarks/fig10_churn.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks import common as C
from repro.core import grnnd
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.recall import recall_at_k


def run(n: int = 2000, backend: str | None = None,
        insert_frac: float = 0.10, batch: int = 0,
        refine_rounds: int = 2) -> list[str]:
    """`backend` applies to the mutation path (seed search + localized
    rounds) AND the rebuild baseline, so the comparison is apples-to-apples;
    recall evaluation keeps the fixed default search path (paper protocol).
    """
    eff, tag = C.resolve_backend(backend)
    if eff == "interpret":
        n = min(n, C.INTERPRET_MAX_N)

    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n, nq=max(64, n // 20)).items():
        n_total = x.shape[0]
        n_ins = max(int(n_total * insert_frac), 1)
        n_base = n_total - n_ins
        x_base, x_new = x[:n_base], x[n_base:]
        b = batch if batch > 0 else n_ins  # default: one insert batch

        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        with C.backend_scope(backend):
            pool_base, t_base = C.timed_build(x_base, cfg)
            pool_full, t_full = C.timed_build(x, cfg)
        rebuild_rounds = cfg.t1 * cfg.t2
        rec_rebuild = C.eval_recall(x, pool_full.ids, q, gt)

        dyn_cfg = DynamicConfig(seed_k=12, seed_ef=C.EF,
                                refine_rounds=refine_rounds,
                                pairs_per_vertex=cfg.pairs_per_vertex)
        with C.backend_scope(backend):
            # compile + warm on a throwaway index by replaying the EXACT
            # batch sequence (the jit caches are shape-keyed — on batch
            # size AND buffer capacity — and process-global, so an
            # identical replay covers every shape the timed run hits,
            # including tail batches and capacity-doubling boundaries)
            warm = DynamicIndex(x_base, pool_base, dyn_cfg)
            for lo in range(0, n_ins, b):
                warm.insert(x_new[lo:lo + b])
            dyn = DynamicIndex(x_base, pool_base, dyn_cfg)
            t0 = time.perf_counter()
            for lo in range(0, n_ins, b):
                dyn.insert(x_new[lo:lo + b])
            t_ins = time.perf_counter() - t0
        ins_per_s = n_ins / t_ins

        # labels of x rows coincide with row indices here, so the static gt
        # applies to the dynamic result unchanged
        rec_dyn = recall_at_k(
            dyn.search(q, k=C.K, ef=C.EF).ids, gt)
        rows.append(C.row(
            f"fig10/{name}/insert{tag}", t_ins,
            f"recall={rec_dyn:.3f} recall_rebuild={rec_rebuild:.3f} "
            f"inserts_per_s={ins_per_s:.0f} "
            f"rounds={dyn.rounds_run} vs_rebuild={rebuild_rounds} "
            f"round_frac={dyn.rounds_run / rebuild_rounds:.2f} "
            f"t_rebuild={t_full:.2f}s backend={eff}",
            bytes_per_vector=C.fp32_bpv(x)))

        # --- delete 10% + compact: recall vs live gt, exact preservation ---
        dels = np.random.default_rng(0).choice(
            n_total, size=n_ins, replace=False)
        dyn.delete(np.sort(dels))
        gt_live = dyn.exact_knn(q, C.K)
        res_before = dyn.search(q, k=C.K, ef=C.EF)
        rec_del = recall_at_k(res_before.ids, gt_live)
        dyn.compact()
        res_after = dyn.search(q, k=C.K, ef=C.EF)
        exact = bool(np.array_equal(np.asarray(res_before.ids),
                                    np.asarray(res_after.ids)))
        rows.append(C.row(
            f"fig10/{name}/delete-compact{tag}", 0.0,
            f"recall_live={rec_del:.3f} tombstoned={n_ins} "
            f"compact_exact={int(exact)} live={dyn.n_live}",
            bytes_per_vector=C.fp32_bpv(x)))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for build + mutation paths "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--n", type=int, default=2000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    ap.add_argument("--batch", type=int, default=0,
                    help="insert batch size (0 = whole 10% in one batch)")
    ap.add_argument("--refine-rounds", type=int, default=2)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(n=args.n, backend=args.backend, batch=args.batch,
                   refine_rounds=args.refine_rounds):
        print(row, flush=True)
