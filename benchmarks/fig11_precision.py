"""Fig 11 (beyond the paper): the precision ladder — bytes/vector, build
time, and QPS-at-recall per storage rung (DESIGN.md §8).

For each dataset the SAME pipeline runs at fp32, bf16, and int8 vector
storage: the graph is BUILT on the quantized store (every init/round
distance in storage-precision space, dequant fused into the kernels) and
QUERIED through the same unified search, with the quantized rungs
re-ranking their final ef candidates against the fp32 tier (the rescoring
pass, core/search.py).  Derived columns record recall with and without
rescoring, so the artifact shows both what quantized traversal alone
loses and what the two-tier layout recovers.

Row names are `fig11/<dataset>/<precision><backend-tag>/ef<ef>`; every
row carries the schema-validated `precision=`/`bpv=` fields
(benchmarks/run.py SMOKE_SCHEMA 2).

    PYTHONPATH=src python benchmarks/fig11_precision.py [--backend ref]
    PYTHONPATH=src python benchmarks/fig11_precision.py --smoke

`--smoke` is the acceptance gate: a tiny interpret-mode sweep whose rows
are parsed and validated in-process (all three precisions present, bf16
bytes/vector ≥ 2x and int8 ≥ 4x below fp32) — non-zero exit on any
violation, so CI catches a broken ladder, not just a slow one.
"""
from __future__ import annotations

import argparse
import sys

if __package__ in (None, ""):  # direct `python benchmarks/fig11_precision.py`
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import common as C
from repro.core import grnnd, vecstore as VS
from repro.core.recall import recall_at_k
from repro.core.search import search

SMOKE_N = 192


def run(n: int = 3000, backend: str | None = None) -> list[str]:
    """`backend` applies to build AND search (both run on the quantized
    store); recall evaluation keeps exact fp32 ground truth."""
    eff, tag = C.resolve_backend(backend)
    interp = eff == "interpret"
    if interp:
        n = min(n, C.INTERPRET_MAX_N)
    nq, repeats, ef = (48, 1, 32) if interp else (200, 2, C.EF)

    rows = []
    datasets = list(C.bench_datasets(n=n, nq=nq).items())
    if interp:
        # interpret mode steps kernel grids from Python: one dataset keeps
        # the 3-precision sweep inside the smoke-job budget (coverage of
        # the other presets comes from the full-scale run of this file)
        datasets = datasets[:1]
    for name, (x, q, gt) in datasets:
        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        for prec in VS.PRECISIONS:
            store = VS.encode(x, prec)
            xt = x if prec == "fp32" else store
            rescore = None if prec == "fp32" else x
            with C.backend_scope(backend):
                pool, t_build = C.timed_build(xt, cfg)
            res, qps = C.timed_search(xt, pool.ids, q, ef=ef,
                                      repeats=repeats, backend=backend,
                                      rescore=rescore)
            rec = recall_at_k(res.ids, gt)
            if rescore is None:
                rec_raw = rec
            else:  # untimed: only the traversal-space recall is wanted
                with C.backend_scope(backend):
                    raw = search(xt, pool.ids, q, k=C.K, ef=ef)
                rec_raw = recall_at_k(raw.ids, gt)
            bpv = store.bytes_per_vector()
            rows.append(C.row(
                f"fig11/{name}/{prec}{tag}/ef{ef}", 1.0 / qps,
                f"recall={rec:.3f} recall_norescore={rec_raw:.3f} "
                f"qps={qps:.0f} build_s={t_build:.2f} "
                f"rescore={int(rescore is not None)} backend={eff}",
                precision=prec, bytes_per_vector=bpv))
    return rows


def validate_precision_rows(parsed: list[dict]) -> None:
    """The fig11 acceptance gate (shared with benchmarks/run.py).

    Raises ValueError unless every precision rung is present and the
    bytes/vector reductions hold: bf16 ≥ 2x and int8 ≥ 4x below the fp32
    rows of the same dataset (scale/offset overhead excluded — it is
    amortized over N and reported separately by VectorStore).
    """
    fig11 = [p for p in parsed if p["name"].startswith("fig11/")]
    by_ds: dict[str, dict[str, float]] = {}
    for p in fig11:
        ds = p["name"].split("/")[1]
        by_ds.setdefault(ds, {})[p["precision"]] = p["bytes_per_vector"]
    if not by_ds:
        raise ValueError("no fig11 rows to validate")
    for ds, prec_bpv in by_ds.items():
        missing = set(VS.PRECISIONS) - set(prec_bpv)
        if missing:
            raise ValueError(f"fig11/{ds} is missing precisions {missing}")
        fp32 = prec_bpv["fp32"]
        if not (fp32 > 0 and prec_bpv["bf16"] <= fp32 / 2
                and prec_bpv["int8"] <= fp32 / 4):
            raise ValueError(
                f"fig11/{ds} bytes/vector reduction violated: {prec_bpv}")


def smoke() -> None:
    """Tiny interpret-mode sweep + in-process schema/ratio validation."""
    from benchmarks.run import parse_row
    rows = run(n=SMOKE_N, backend="interpret")
    for r in rows:
        print(r, flush=True)
    validate_precision_rows([parse_row(r) for r in rows])
    print("# fig11 smoke: schema + bytes/vector reductions OK",
          file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for build + search "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--n", type=int, default=3000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode sweep, self-validating "
                         "(non-zero exit on schema/ratio violations)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        for row in run(n=args.n, backend=args.backend):
            print(row, flush=True)
