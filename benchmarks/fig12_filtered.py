"""Fig 12 (beyond the paper): filtered search — QPS and recall vs predicate
selectivity (DESIGN.md §9).

Each dataset gets synthetic per-vertex labels uniform over a 100-label
space; each query carries a random allowed-label predicate whose label
mass sets the SELECTIVITY (fraction of the corpus the query may return).
The sweep runs the same filtered search at every precision rung (the
quantized rungs rescore against the fp32 tier) and three selectivities —
the CAGRA-class filtered-mode protocol: recall is scored against brute
force over each query's ALLOWED subset, and every returned id must
satisfy its predicate (the hard invariant, reported as `pred_ok=`).

The effective ef follows the §9.3 over-fetch policy — raised toward
~4·k/selectivity, clamped at N — so ~k allowed survivors exist even at
1% selectivity; the reported QPS therefore falls as selectivity drops,
which is the honest cost curve of route-through filtering.

Row names are `fig12/<dataset>/<precision><backend-tag>/s<selectivity>`;
every row carries the schema-validated `precision=`/`bpv=` fields plus
`selectivity=` (benchmarks/run.py SMOKE_SCHEMA 3).

    PYTHONPATH=src python benchmarks/fig12_filtered.py [--backend ref]
    PYTHONPATH=src python benchmarks/fig12_filtered.py --smoke

`--smoke` is the acceptance gate: a tiny interpret-mode sweep whose rows
are parsed and validated in-process — all three precision rungs at all
three selectivities, filtered recall@10 >= 0.90 against allowed-subset
brute force, and pred_ok == 1.0 on every row — non-zero exit on any
violation.
"""
from __future__ import annotations

import argparse
import re
import sys

if __package__ in (None, ""):  # direct `python benchmarks/fig12_filtered.py`
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax

from benchmarks import common as C
from repro.core import grnnd, labels as L, vecstore as VS
from repro.core.search import EF_CEILING, overfetch_ef

SMOKE_N = 192
SELECTIVITIES = (0.01, 0.1, 0.5)
N_LABELS = 100  # label-space width: 1% selectivity = exactly one label
RECALL_FLOOR = 0.90

_REC_RE = re.compile(r"(?:^|\s)recall=(\S+)")
_PRED_RE = re.compile(r"(?:^|\s)pred_ok=(\S+)")


def run(n: int = 3000, backend: str | None = None,
        selectivities=SELECTIVITIES) -> list[str]:
    """`backend` applies to build AND filtered search; the allowed-subset
    ground truth keeps exact fp32 ambient-backend brute force."""
    eff, tag = C.resolve_backend(backend)
    interp = eff == "interpret"
    if interp:
        n = min(n, C.INTERPRET_MAX_N)
    # fewer queries / repeats than fig11: the low-selectivity cells run
    # at over-fetched ef (up to EF_CEILING), each costing ~10x an ef=48
    # search — nq=96 keeps the full sweep in minutes, not hours
    nq, repeats = (32, 1) if interp else (96, 1)
    # interpret mode steps kernel grids from Python: the narrower fast-tier
    # graph shape keeps the sweep inside the smoke-job budget (full-scale
    # runs use the fig10/fig11 build shape)
    cfg = (grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
           if interp else
           grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                             pairs_per_vertex=24))

    rows = []
    datasets = list(C.bench_datasets(n=n, nq=nq).items())
    if interp:
        # one dataset keeps the 3-rung x 3-selectivity sweep tractable
        # (same budget rationale as fig11's smoke)
        datasets = datasets[:1]
    for name, (x, q, gt) in datasets:
        n_act = x.shape[0]
        vlab = jax.random.randint(jax.random.PRNGKey(0xf12), (n_act,), 0,
                                  N_LABELS)
        lstore = L.encode_labels(vlab, N_LABELS)
        for prec in VS.PRECISIONS:
            store = VS.encode(x, prec)
            xt = x if prec == "fp32" else store
            rescore = None if prec == "fp32" else x
            with C.backend_scope(backend):
                pool, t_build = C.timed_build(xt, cfg)
            for sel in selectivities:
                fw = L.random_query_filters(jax.random.PRNGKey(0xf13), nq,
                                            N_LABELS, sel)
                ef = overfetch_ef(n_act, C.K, sel, ef=32 if interp else C.EF)
                res, qps = C.timed_search(xt, pool.ids, q, ef=ef,
                                          repeats=repeats, backend=backend,
                                          rescore=rescore,
                                          labels=lstore.words, filter=fw)
                # ground truth over the allowed subset: ambient backend,
                # exact fp32 — never the timed/interpret path
                gt_f = L.filtered_brute_force(x, q, fw, lstore.words, C.K)
                rec = L.filtered_recall_at_k(res.ids, gt_f)
                pred = L.predicate_fraction(res.ids, fw, lstore.words)
                rows.append(C.row(
                    f"fig12/{name}/{prec}{tag}/s{sel:g}", 1.0 / qps,
                    f"recall={rec:.3f} pred_ok={pred:.3f} qps={qps:.0f} "
                    f"ef={ef} selectivity={sel:g} build_s={t_build:.2f} "
                    f"rescore={int(rescore is not None)} backend={eff}",
                    precision=prec,
                    bytes_per_vector=store.bytes_per_vector()))
    return rows


def validate_filtered_rows(parsed: list[dict]) -> None:
    """The fig12 acceptance gate (shared with benchmarks/run.py).

    Raises ValueError unless, per dataset, every precision rung appears at
    every sweep selectivity, and EVERY fig12 row holds the two contracts:
    filtered recall@10 >= 0.90 against allowed-subset brute force, and
    pred_ok == 1.0 (100% of returned ids satisfy their predicate — the
    hard invariant, on all precision rungs).
    """
    fig12 = [p for p in parsed if p["name"].startswith("fig12/")]
    if not fig12:
        raise ValueError("no fig12 rows to validate")
    seen: dict[str, set] = {}
    for p in fig12:
        ds = p["name"].split("/")[1]
        if p.get("selectivity") is None:
            raise ValueError(f"fig12 row lacks selectivity=: {p['name']}")
        seen.setdefault(ds, set()).add((p["precision"], p["selectivity"]))
        rec = _REC_RE.search(p["derived"])
        pred = _PRED_RE.search(p["derived"])
        if not rec or not pred:
            raise ValueError(f"fig12 row lacks recall=/pred_ok=: {p!r}")
        if float(rec.group(1)) < RECALL_FLOOR:
            raise ValueError(
                f"{p['name']}: filtered recall {rec.group(1)} below the "
                f"{RECALL_FLOOR} floor")
        if float(pred.group(1)) != 1.0:
            raise ValueError(
                f"{p['name']}: pred_ok={pred.group(1)} — returned ids "
                "violate their predicate (hard invariant)")
    want = {(prec, float(s)) for prec in VS.PRECISIONS
            for s in SELECTIVITIES}
    for ds, got in seen.items():
        if not want <= got:
            raise ValueError(
                f"fig12/{ds} is missing (precision, selectivity) cells: "
                f"{sorted(want - got)}")


def smoke() -> None:
    """Tiny interpret-mode sweep + in-process contract validation."""
    from benchmarks.run import parse_row
    rows = run(n=SMOKE_N, backend="interpret")
    for r in rows:
        print(r, flush=True)
    validate_filtered_rows([parse_row(r) for r in rows])
    print("# fig12 smoke: recall floor + predicate invariant OK",
          file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for build + filtered search "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--n", type=int, default=3000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode sweep, self-validating "
                         "(non-zero exit on recall/predicate violations)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        for row in run(n=args.n, backend=args.backend):
            print(row, flush=True)
