"""Fig 13 (beyond the paper): corpus-sharded index — the N-ceiling sweep
(DESIGN.md §11).

The replicated layout puts every O(N) operand (vectors, graph, rescore
tier, labels, id map) on EVERY device, so the largest servable corpus is
capped by ONE device's memory.  Corpus sharding slices those operands
into S contiguous partitions — each device holds N/S rows — and runs the
same beam search with per-step owner-combines (bitwise-identical to the
replicated search; tests/test_corpus_shard.py is the lock).  This sweep
measures both sides of that trade:

  * memory: per-shard bytes of O(N) index state vs the replicated
    baseline (`core.corpus_shard.memory_report`) — the ceiling moves by
    ~1/S, which is the entire point;
  * quality: the divide-and-conquer build (`sharded_build`: independent
    per-partition GRNND + cross-boundary merge-refine) must still clear
    the tests/test_recall.py floor (0.86 @ ef=48), searched through the
    corpus-sharded path itself.

Row names are `fig13/<dataset>/S<shards><backend-tag>`; every row
carries the schema-validated `corpus_shards=` field (benchmarks/run.py
SMOKE_SCHEMA 5) plus `shard_mb=`/`repl_mb=` for the memory story.

    PYTHONPATH=src python benchmarks/fig13_corpus_sharded.py [--backend ref]
    PYTHONPATH=src python benchmarks/fig13_corpus_sharded.py --smoke

`--smoke` is the acceptance gate: a tiny interpret-mode sweep whose rows
are parsed and validated in-process — S=1 and S>1 cells per dataset,
recall@10 >= 0.86 on every row, and per-shard bytes strictly below the
replicated baseline wherever S>1 — non-zero exit on any violation.
"""
from __future__ import annotations

import argparse
import re
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/fig13_corpus_sharded.py`
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax

from benchmarks import common as C
from repro.core import corpus_shard as CS, grnnd, recall as R

SMOKE_N = 192
SHARD_COUNTS = (1, 2, 4)
RECALL_FLOOR = 0.86  # tests/test_recall.py disordered floor, ef=48

_REC_RE = re.compile(r"(?:^|\s)recall=(\S+)")
_SHARD_MB_RE = re.compile(r"(?:^|\s)shard_mb=(\S+)")
_REPL_MB_RE = re.compile(r"(?:^|\s)repl_mb=(\S+)")


def run(n: int = 3000, backend: str | None = None,
        shard_counts=SHARD_COUNTS) -> list[str]:
    """`backend` applies to build AND sharded search; ground truth keeps
    exact fp32 ambient-backend brute force (from bench_datasets)."""
    eff, tag = C.resolve_backend(backend)
    interp = eff == "interpret"
    if interp:
        n = min(n, C.INTERPRET_MAX_N)
        # interpret steps kernel grids from Python; two shard counts
        # already exercise the S=1 fallback and the real sharded path
        shard_counts = tuple(s for s in shard_counts if s <= 2)
    nq, repeats = (32, 1) if interp else (96, 3)
    # interpret: fast-tier shape (Python-stepped kernel grids); full scale:
    # the fig10/fig11/fig12 build shape — the fast-tier graph is too sparse
    # to clear the recall floor at n=3000
    cfg = (grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
           if interp else
           grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                             pairs_per_vertex=24))

    rows = []
    datasets = list(C.bench_datasets(n=n, nq=nq).items())
    if interp:
        datasets = datasets[:1]  # same smoke-budget rationale as fig12
    for name, (x, q, gt) in datasets:
        for s in shard_counts:
            # full scale needs two extra merge-refine rounds for the
            # hardest (960-d gist-like) corpus to clear the floor at S=4;
            # the tiny smoke corpus converges at the default
            mr = 3 if interp else 5
            with C.backend_scope(backend):
                t0 = time.perf_counter()
                pool = CS.sharded_build(jax.random.PRNGKey(2), x, cfg, s,
                                        merge_rounds=mr)
                pool.ids.block_until_ready()
                t_build = time.perf_counter() - t0
                idx = CS.shard(x, pool.ids, s)
                res = idx.search(q, k=C.K, ef=C.EF)  # compile + warm
                res.ids.block_until_ready()
                times = []
                for _ in range(repeats):
                    t1 = time.perf_counter()
                    res = idx.search(q, k=C.K, ef=C.EF)
                    res.ids.block_until_ready()
                    times.append(time.perf_counter() - t1)
            qps = q.shape[0] / min(times)
            rec = R.recall_at_k(res.ids, gt)
            mem = CS.memory_report(idx)
            rows.append(C.row(
                f"fig13/{name}/S{s}{tag}", 1.0 / qps,
                f"recall={rec:.3f} qps={qps:.0f} corpus_shards={s} "
                f"shard_mb={mem['per_shard_bytes'] / 2**20:.4f} "
                f"repl_mb={mem['replicated_bytes'] / 2**20:.4f} "
                f"build_s={t_build:.2f} ef={C.EF} backend={eff}",
                bytes_per_vector=C.fp32_bpv(x)))
    return rows


def validate_corpus_rows(parsed: list[dict]) -> None:
    """The fig13 acceptance gate (shared with benchmarks/run.py).

    Raises ValueError unless every fig13 row carries `corpus_shards=`
    and clears the recall floor, every S>1 row holds strictly less
    per-shard memory than its replicated baseline (the N-ceiling claim),
    and each dataset covers both the S=1 baseline and at least one
    genuinely sharded cell.
    """
    fig13 = [p for p in parsed if p["name"].startswith("fig13/")]
    if not fig13:
        raise ValueError("no fig13 rows to validate")
    seen: dict[str, set] = {}
    for p in fig13:
        ds = p["name"].split("/")[1]
        s = p.get("corpus_shards")
        if s is None:
            raise ValueError(f"fig13 row lacks corpus_shards=: {p['name']}")
        seen.setdefault(ds, set()).add(s)
        rec = _REC_RE.search(p["derived"])
        if not rec:
            raise ValueError(f"fig13 row lacks recall=: {p!r}")
        if float(rec.group(1)) < RECALL_FLOOR:
            raise ValueError(
                f"{p['name']}: sharded-build recall {rec.group(1)} below "
                f"the {RECALL_FLOOR} floor")
        shard_mb = _SHARD_MB_RE.search(p["derived"])
        repl_mb = _REPL_MB_RE.search(p["derived"])
        if not shard_mb or not repl_mb:
            raise ValueError(f"fig13 row lacks shard_mb=/repl_mb=: {p!r}")
        if s > 1 and float(shard_mb.group(1)) >= float(repl_mb.group(1)):
            raise ValueError(
                f"{p['name']}: per-shard memory {shard_mb.group(1)}MB is "
                f"not below the replicated {repl_mb.group(1)}MB — the "
                "N-ceiling claim fails")
    for ds, got in seen.items():
        if 1 not in got or not any(s > 1 for s in got):
            raise ValueError(
                f"fig13/{ds} must cover the S=1 baseline and an S>1 "
                f"sharded cell; got S={sorted(got)}")


def smoke() -> None:
    """Tiny interpret-mode sweep + in-process contract validation."""
    from benchmarks.run import parse_row
    rows = run(n=SMOKE_N, backend="interpret")
    for r in rows:
        print(r, flush=True)
    validate_corpus_rows([parse_row(r) for r in rows])
    print("# fig13 smoke: recall floor + memory-ceiling contract OK",
          file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for build + sharded search "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--n", type=int, default=3000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode sweep, self-validating "
                         "(non-zero exit on recall/memory violations)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        for row in run(n=args.n, backend=args.backend):
            print(row, flush=True)
