"""Fig 14 (beyond the paper): serving-engine latency vs offered load.

The paper's figures measure throughput on closed-loop fixed batches; a
serving deployment sees an open-loop stream of small heterogeneous
requests, and the number that matters is tail latency as the offered
load approaches the engine's capacity.  This sweep drives the
continuous-batching engine (`serve/ann_engine.py`, DESIGN.md §12) with a
Poisson trace of mixed-(k, ef) requests at a ladder of offered-QPS
fractions of the measured closed-loop capacity, and reports nearest-rank
p50/p99 per-request latency, achieved QPS, mean batch occupancy, and the
compiled-bucket count per load point.

Row names are `fig14/<dataset>/load<pct><backend-tag>`; every row
carries the schema-validated `p50_ms=`/`p99_ms=`/`qps=` fields
(benchmarks/run.py SMOKE_SCHEMA 6) plus `offered_qps=`/`capacity_qps=`
for the load story.

    PYTHONPATH=src python benchmarks/fig14_serving.py [--backend ref]
    PYTHONPATH=src python benchmarks/fig14_serving.py --smoke

`--smoke` is the acceptance gate: a tiny interpret-mode sweep whose rows
are parsed and validated in-process — at least two load points per
dataset, every request completed, p50 <= p99, achieved QPS positive —
non-zero exit on any violation.  Latency MAGNITUDES are not gated (CI
wall clocks are noisy); the contract is the reporting surface.
"""
from __future__ import annotations

import argparse
import dataclasses
import re
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/fig14_serving.py`
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks import common as C
from repro.core import grnnd, recall as R
from repro.serve import ann_engine as AE

SMOKE_N = 192
K_CHOICES = (5, 10)
LOAD_FRACS = (0.25, 0.5, 1.0)

_P50_RE = re.compile(r"(?:^|\s)p50_ms=(\S+)")
_P99_RE = re.compile(r"(?:^|\s)p99_ms=(\S+)")
_QPS_RE = re.compile(r"(?:^|\s)qps=(\S+)")
_OFFERED_RE = re.compile(r"(?:^|\s)offered_qps=(\S+)")
_COMPLETED_RE = re.compile(r"(?:^|\s)completed=(\S+)")


def _warm_buckets(worker, cfg, q, ef_choices) -> None:
    """Compile every (Q bucket, ef) trace the engine can emit for this
    config, so measured replays see warm jit caches in every bucket (the
    engine's own warm-up would only touch the shapes one load level
    happens to produce)."""
    for ef in ef_choices:
        k_exec = min(cfg.k_cap, ef)
        qb = 1
        while qb <= cfg.max_batch:
            worker.search_batch(np.repeat(q[:1], qb, axis=0), k=k_exec,
                                ef=ef, fwords=None)
            qb *= 2


def run(n: int = 3000, backend: str | None = None,
        load_fracs=LOAD_FRACS) -> list[str]:
    """`backend` applies to the engine's search path; recall is scored
    against exact fp32 brute force (from bench_datasets)."""
    eff, tag = C.resolve_backend(backend)
    interp = eff == "interpret"
    if interp:
        n = min(n, C.INTERPRET_MAX_N)
        load_fracs = tuple(load_fracs)[-2:]  # two points bound the smoke
    requests = 48 if interp else 256
    ef_choices = (C.EF,) if interp else (32, 64)
    max_batch = 8 if interp else 32
    # interpret: the fast-tier build shape (Python-stepped kernel grids);
    # full scale: the fig6/fig13 build shape
    cfg_b = (grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
             if interp else
             grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                               pairs_per_vertex=24))
    ecfg = AE.EngineConfig(max_batch=max_batch,
                           ef_menu=tuple(sorted(set(ef_choices))))

    rows = []
    datasets = list(C.bench_datasets(n=n, nq=requests).items())
    if interp:
        datasets = datasets[:1]  # same smoke-budget rationale as fig12/13
    for name, (x, q, gt) in datasets:
        qn = np.asarray(q, np.float32)
        gtn = np.asarray(gt)
        with C.backend_scope(backend):
            pool, _ = C.timed_build(x, cfg_b)
            worker = AE.StaticWorker(x, pool.ids)
            _warm_buckets(worker, ecfg, qn, ef_choices)
            eng = AE.AnnEngine(worker, ecfg)

            # closed-loop capacity probe: everything arrives at t~0, the
            # drain rate is the ceiling the load ladder is scaled from
            def make_trace(offered):
                return AE.synth_trace(np.random.default_rng(3), qn,
                                      offered_qps=offered,
                                      k_choices=K_CHOICES,
                                      ef_choices=ef_choices)
            probe = AE.replay(eng, [dataclasses.replace(ev, t=0.0)
                                    for ev in make_trace(1.0)])
            for rid in probe.values():
                eng.take_result(rid)
            capacity = max(eng.stats().qps, 1.0)

            for frac in load_fracs:
                eng.reset_stats()
                offered = frac * capacity
                trace = make_trace(offered)
                rids = AE.replay(eng, trace)
                s = eng.stats()
                recs = []
                for i, rid in rids.items():
                    res = eng.take_result(rid)
                    recs.append(R.recall_at_k(
                        res.ids[None], gtn[i, : trace[i].k][None]))
                rec = sum(recs) / max(len(recs), 1)
                rows.append(C.row(
                    f"fig14/{name}/load{int(round(frac * 100))}{tag}",
                    s.p50_ms * 1e-3,
                    f"p50_ms={s.p50_ms:.2f} p99_ms={s.p99_ms:.2f} "
                    f"qps={s.qps:.1f} offered_qps={offered:.1f} "
                    f"capacity_qps={capacity:.1f} "
                    f"occupancy={s.mean_occupancy:.2f} "
                    f"buckets={s.n_buckets} completed={s.n_completed} "
                    f"rejected={s.n_rejected} recall={rec:.3f} "
                    f"backend={eff}",
                    bytes_per_vector=C.fp32_bpv(x)))
    return rows


def validate_serving_rows(parsed: list[dict]) -> None:
    """The fig14 acceptance gate (shared with benchmarks/run.py).

    Raises ValueError unless every fig14 row carries the SMOKE_SCHEMA 6
    reporting surface — parseable `p50_ms=`/`p99_ms=`/`qps=` with
    p50 <= p99 and achieved QPS positive — every admitted request
    completed, and each dataset covers at least two load points.
    Latency magnitudes are deliberately NOT gated (wall-clock noise).
    """
    fig14 = [p for p in parsed if p["name"].startswith("fig14/")]
    if not fig14:
        raise ValueError("no fig14 rows to validate")
    seen: dict[str, set] = {}
    for p in fig14:
        ds, cell = p["name"].split("/")[1:3]
        seen.setdefault(ds, set()).add(cell)
        vals = {}
        for field, rx in (("p50_ms", _P50_RE), ("p99_ms", _P99_RE),
                          ("qps", _QPS_RE), ("offered_qps", _OFFERED_RE),
                          ("completed", _COMPLETED_RE)):
            m = rx.search(p["derived"])
            if not m:
                raise ValueError(f"fig14 row lacks {field}=: {p['name']}")
            vals[field] = float(m.group(1))
        if vals["p50_ms"] < 0 or vals["p99_ms"] < vals["p50_ms"]:
            raise ValueError(
                f"{p['name']}: p50/p99 out of order "
                f"({vals['p50_ms']} / {vals['p99_ms']})")
        if vals["qps"] <= 0 or vals["offered_qps"] <= 0:
            raise ValueError(f"{p['name']}: non-positive QPS")
        if vals["completed"] < 1:
            raise ValueError(f"{p['name']}: no request completed")
    for ds, cells in seen.items():
        if len(cells) < 2:
            raise ValueError(
                f"fig14/{ds} must cover at least two load points; "
                f"got {sorted(cells)}")


def smoke() -> None:
    """Tiny interpret-mode sweep + in-process contract validation."""
    from benchmarks.run import parse_row
    rows = run(n=SMOKE_N, backend="interpret")
    for r in rows:
        print(r, flush=True)
    validate_serving_rows([parse_row(r) for r in rows])
    print("# fig14 smoke: latency/QPS reporting contract OK",
          file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for the engine's search path "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--n", type=int, default=3000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode sweep, self-validating "
                         "(non-zero exit on reporting-contract violations)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        t0 = time.time()
        for row in run(n=args.n, backend=args.backend):
            print(row, flush=True)
        print(f"# fig14 done in {time.time() - t0:.1f}s", file=sys.stderr)
