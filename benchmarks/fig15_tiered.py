"""Fig 15 (beyond the paper): tiered vector storage — device-hot
traversal, host-cold fp32 rescore (DESIGN.md §13).

The precision ladder (fig11) cut traversal-tier bytes/vector 4x, but the
fp32 rescore tier still sat in device memory — N·D·4 bytes that the
search touches only ef rows of per query.  `--tier host` pins that tier
on the CPU backend (`vecstore.HostTier`): device memory holds the
quantized tier + graph only, and the re-rank gathers ef·D fp32 bytes per
query across the host boundary.  This sweep measures both sides of the
placement trade, per quantized rung:

  * memory: `rescore_dev_mb=` — the fp32 tier's device-resident MB
    (N·D·4/2^20 under device placement, 0.0 under host — the N-ceiling
    lift the fig15 smoke gates on) next to `host_mb=`, where the bytes
    went;
  * latency: `qps=` per (rung, tier) — the host rows price the
    cross-boundary gather against the device-resident rescore;
  * exactness: `parity=1` on every host row — ids, distances, and
    n_expanded compared bitwise against the device-tier result IN-RUN
    (the tests/test_tiered.py contract, re-checked on real data here).

Row names are `fig15/<dataset>/<rung>/<tier><backend-tag>`; every row
carries the schema-validated `tier=` field (benchmarks/run.py
SMOKE_SCHEMA 7).

    PYTHONPATH=src python benchmarks/fig15_tiered.py [--backend ref]
    PYTHONPATH=src python benchmarks/fig15_tiered.py --smoke

`--smoke` is the acceptance gate: a tiny interpret-mode sweep whose rows
are parsed and validated in-process — both tiers per (dataset, rung),
parity=1 and zero device rescore bytes on every host row — non-zero
exit on any violation.
"""
from __future__ import annotations

import argparse
import re
import sys

if __package__ in (None, ""):  # direct `python benchmarks/fig15_tiered.py`
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks import common as C
from repro.core import grnnd, vecstore as VS
from repro.core.recall import recall_at_k

SMOKE_N = 192
RUNGS = ("int8", "bf16")
TIERS = VS.PLACEMENTS  # ("device", "host")

_REC_RE = re.compile(r"(?:^|\s)recall=(\S+)")
_PARITY_RE = re.compile(r"(?:^|\s)parity=(\S+)")
_RDEV_RE = re.compile(r"(?:^|\s)rescore_dev_mb=(\S+)")
_HOST_RE = re.compile(r"(?:^|\s)host_mb=(\S+)")


def _same(a, b) -> bool:
    return (np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
            and np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
            and np.array_equal(np.asarray(a.n_expanded),
                               np.asarray(b.n_expanded)))


def run(n: int = 3000, backend: str | None = None) -> list[str]:
    """The fig11 pipeline per rung (graph BUILT on the quantized store,
    traversal in storage precision), then both placements of the fp32
    rescore tier searched over the SAME graph — the placement axis is a
    pure query-path property, so the host/device pair is bitwise
    comparable."""
    eff, tag = C.resolve_backend(backend)
    interp = eff == "interpret"
    if interp:
        n = min(n, C.INTERPRET_MAX_N)
    nq, repeats, ef = (32, 1, 32) if interp else (96, 3, C.EF)

    rows = []
    datasets = list(C.bench_datasets(n=n, nq=nq).items())
    if interp:
        datasets = datasets[:1]  # same smoke-budget rationale as fig11/13
    for name, (x, q, gt) in datasets:
        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        rescore_mb = x.shape[0] * x.shape[1] * 4 / 2**20
        for rung in RUNGS:
            store = VS.encode(x, rung)
            with C.backend_scope(backend):
                pool, _ = C.timed_build(store, cfg)
            results = {}
            for tier in TIERS:  # device first: the host row checks parity
                resc = VS.HostTier(x) if tier == "host" else x
                res, qps = C.timed_search(store, pool.ids, q, ef=ef,
                                          repeats=repeats, backend=backend,
                                          rescore=resc)
                results[tier] = res
                rec = recall_at_k(res.ids, gt)
                host = tier == "host"
                parity = ("" if not host else
                          f"parity={int(_same(results['device'], res))} ")
                rows.append(C.row(
                    f"fig15/{name}/{rung}/{tier}{tag}", 1.0 / qps,
                    f"recall={rec:.3f} qps={qps:.0f} tier={tier} {parity}"
                    f"rescore_dev_mb={0.0 if host else rescore_mb:.4f} "
                    f"host_mb={rescore_mb if host else 0.0:.4f} "
                    f"ef={ef} backend={eff}",
                    precision=rung,
                    bytes_per_vector=store.bytes_per_vector()))
    return rows


def validate_tiered_rows(parsed: list[dict]) -> None:
    """The fig15 acceptance gate (shared with benchmarks/run.py).

    Raises ValueError unless every fig15 row carries a valid `tier=`,
    every host row shows ZERO device-resident rescore bytes (the §13
    placement contract) and in-run bitwise parity against its device
    twin, and each (dataset, rung) covers both placements.
    """
    fig15 = [p for p in parsed if p["name"].startswith("fig15/")]
    if not fig15:
        raise ValueError("no fig15 rows to validate")
    seen: dict[tuple, set] = {}
    for p in fig15:
        _, ds, rung, _cell = p["name"].split("/", 3)
        tier = p.get("tier")
        if tier not in VS.PLACEMENTS:
            raise ValueError(f"fig15 row lacks a valid tier=: {p['name']}")
        seen.setdefault((ds, rung), set()).add(tier)
        if not _REC_RE.search(p["derived"]):
            raise ValueError(f"fig15 row lacks recall=: {p!r}")
        rdev = _RDEV_RE.search(p["derived"])
        hmb = _HOST_RE.search(p["derived"])
        if not rdev or not hmb:
            raise ValueError(
                f"fig15 row lacks rescore_dev_mb=/host_mb=: {p!r}")
        if tier == "host":
            if float(rdev.group(1)) != 0.0:
                raise ValueError(
                    f"{p['name']}: host-tier row reports "
                    f"{rdev.group(1)}MB of device-resident rescore bytes "
                    "— the §13 placement contract fails")
            par = _PARITY_RE.search(p["derived"])
            if not par or par.group(1) != "1":
                raise ValueError(
                    f"{p['name']}: host tier is not bitwise-equal to the "
                    "device tier (parity != 1)")
        elif float(rdev.group(1)) <= 0.0:
            raise ValueError(
                f"{p['name']}: device-tier row reports no device rescore "
                "bytes — the memory comparison is vacuous")
    for (ds, rung), got in seen.items():
        if got != set(VS.PLACEMENTS):
            raise ValueError(
                f"fig15/{ds}/{rung} must cover both placements; got "
                f"{sorted(got)}")


def smoke() -> None:
    """Tiny interpret-mode sweep + in-process contract validation."""
    from benchmarks.run import parse_row
    rows = run(n=SMOKE_N, backend="interpret")
    for r in rows:
        print(r, flush=True)
    validate_tiered_rows([parse_row(r) for r in rows])
    print("# fig15 smoke: parity + zero-device-rescore contract OK",
          file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for build + search (default: "
                         "current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--n", type=int, default=3000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode sweep, self-validating "
                         "(non-zero exit on parity/placement violations)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        for row in run(n=args.n, backend=args.backend):
            print(row, flush=True)
