"""Fig 16 (beyond the paper): kNN-LM retrieval-in-the-loop decode
(retrieval/knn_lm.py + serve/engine.py, DESIGN.md §14).

The whole-system scenario: a `DynamicDatastore` (int8 traversal + fp32
rescore over a DynamicIndex) of the LM's own (hidden, next-token) pairs
sits inside `ServeEngine`'s decode loop — `logit_hook` queries it with
every step's post-`final_norm` hidden state and fuses the vote into the
logits, `token_hook` streams the generation's new pairs back into the
index while it decodes.  Two rows per run measure the price and the win:

  * `fig16/<arch>/lm<tag>` — the pure-LM decode baseline: `tok_s=`
    (end-to-end generate throughput, compile-excluded) and `lm_nll=`
    (teacher-forced NLL on the datastore's own corpus);
  * `fig16/<arch>/knn-<rung><tag>` — the same engine with retrieval
    fused in (`lam=`) and streaming inserts live (`grew=` rows added
    during the timed generation): `tok_s=` now prices the per-step
    retrieval + insert, and `fused_nll=` must beat `lm_nll=` on the
    memorization corpus — queries AT stored keys retrieve their own
    next token, the classic kNN-LM win, so fused-worse-than-pure means
    the retrieval path (not the LM) is broken.

That ordering is the validation gate (`validate_knn_rows`, enforced on
every smoke artifact by benchmarks/run.py SMOKE_SCHEMA 8): fused NLL <=
pure-LM NLL, positive throughput on every row, and both the baseline
and at least one retrieval row present.

    PYTHONPATH=src python benchmarks/fig16_knn_lm.py [--backend ref]
    PYTHONPATH=src python benchmarks/fig16_knn_lm.py --smoke
"""
from __future__ import annotations

import argparse
import re
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/fig16_knn_lm.py`
    import pathlib

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.configs import get_arch, reduced
from repro.core.grnnd import GRNNDConfig
from repro.data import pipeline as PIPE
from repro.models import transformer as T
from repro.retrieval import knn_lm
from repro.serve.engine import ServeEngine

SMOKE_N = 192
ARCH = "gemma3-1b"
RUNG = "int8"
LAM = 0.4
NLL_EPS = 1e-6  # float tolerance on the fused <= pure gate

_TOKS_RE = re.compile(r"(?:^|\s)tok_s=(\S+)")
_FNLL_RE = re.compile(r"(?:^|\s)fused_nll=(\S+)")
_LNLL_RE = re.compile(r"(?:^|\s)lm_nll=(\S+)")


def _nll(logits, targets) -> float:
    lsm = jax.nn.log_softmax(logits, axis=-1)
    return float(-jnp.take_along_axis(lsm, targets[:, None], axis=-1).mean())


def _timed_generate(eng, prompt, new_tokens: int) -> float:
    """Compile-excluded tokens/sec of one warm `generate` call."""
    eng.generate(prompt, max_new_tokens=new_tokens)  # compile + warm
    t0 = time.perf_counter()
    out = eng.generate(prompt, max_new_tokens=new_tokens)
    out["tokens"].block_until_ready()
    dt = time.perf_counter() - t0
    return out["tokens"].size / dt


def run(n: int = 2048, backend: str | None = None,
        new_tokens: int = 8) -> list[str]:
    """Build the memorization datastore, then decode through one engine
    twice — hooks gated OFF for the pure-LM baseline row, ON for the
    retrieval row — so both rows share every jit cache and the delta is
    the retrieval work itself."""
    eff, tag = C.resolve_backend(backend)
    if eff == "interpret":
        n = min(n, C.INTERPRET_MAX_N)

    cfg = reduced(get_arch(ARCH))
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    # the memorization corpus: every (hidden, next-token) pair both feeds
    # the datastore and scores the NLL gate — queries AT stored keys
    seq = 33
    b = -(-n // (seq - 1))
    batch = PIPE.batch_for_step(cfg, 0, b, seq)
    hidden, _ = T.forward(params, cfg, batch, act_dtype=jnp.float32,
                          remat=False, return_hidden=True)
    keys = hidden[:, :-1].reshape(-1, cfg.d_model)[:n]
    vals = batch["tokens"][:, 1:].reshape(-1)[:n]

    with C.backend_scope(backend):
        ds = knn_lm.DynamicDatastore.build(
            jax.random.PRNGKey(3), keys, vals, cfg.vocab,
            build_cfg=GRNNDConfig(s=8, r=16, t1=2, t2=3,
                                  pairs_per_vertex=16),
            precision=RUNG, k=8, ef=32)
    bpv = ds.index.store.bytes_per_vector()

    lm_logits = T.lm_logits(params, cfg, hidden[:, :-1])
    lm_logits = lm_logits.reshape(-1, cfg.vocab)[:n]
    lm_nll = _nll(lm_logits, vals)
    with C.backend_scope(backend):
        klp = ds.knn_log_probs(keys)
    fused_nll = _nll(knn_lm.fuse(lm_logits, klp, lam=LAM), vals)

    # one engine, hooks gated by a flag: the lm row and the knn row share
    # the prefill/decode jit caches, so tok_s deltas isolate retrieval
    gate = {"on": False}
    fuse_hook = knn_lm.make_logit_hook(ds, lam=LAM)
    stream = knn_lm.make_stream_hook(ds, insert_every=4)

    def logit_hook(lm_lo, hid):
        return fuse_hook(lm_lo, hid) if gate["on"] else lm_lo

    def token_hook(hid, tok):
        if gate["on"]:
            stream(hid, tok)

    prompt = {"tokens": batch["tokens"][:2, :8]}
    eng = ServeEngine(cfg, params, s_max=8 + new_tokens,
                      act_dtype=jnp.float32,
                      logit_hook=logit_hook, token_hook=token_hook)

    rows = []
    tok_s = _timed_generate(eng, prompt, new_tokens)
    rows.append(C.row(
        f"fig16/{ARCH}/lm{tag}", 1.0 / tok_s,
        f"tok_s={tok_s:.1f} lm_nll={lm_nll:.4f} lam=0.0 "
        f"new_tokens={new_tokens} n={n} backend={eff}",
        precision="fp32", bytes_per_vector=0.0))

    gate["on"] = True
    with C.backend_scope(backend):
        n0 = len(ds)
        tok_s = _timed_generate(eng, prompt, new_tokens)
        stream.flush()
    rows.append(C.row(
        f"fig16/{ARCH}/knn-{RUNG}{tag}", 1.0 / tok_s,
        f"tok_s={tok_s:.1f} fused_nll={fused_nll:.4f} "
        f"lm_nll={lm_nll:.4f} lam={LAM} grew={len(ds) - n0} "
        f"new_tokens={new_tokens} n={n} backend={eff}",
        precision=RUNG, bytes_per_vector=bpv))
    return rows


def validate_knn_rows(parsed: list[dict]) -> None:
    """The fig16 acceptance gate (shared with benchmarks/run.py).

    Raises ValueError unless the family covers both the pure-LM baseline
    and a retrieval row, every row reports positive decode throughput
    (`tok_s=`), and every retrieval row's fused NLL beats the pure-LM
    NLL on the memorization corpus — the end-to-end proof that the
    decode-time retrieval hook actually retrieves.
    """
    fig16 = [p for p in parsed if p["name"].startswith("fig16/")]
    if not fig16:
        raise ValueError("no fig16 rows to validate")
    shapes = set()
    for p in fig16:
        toks = _TOKS_RE.search(p["derived"])
        if not toks or float(toks.group(1)) <= 0.0:
            raise ValueError(f"fig16 row lacks positive tok_s=: {p!r}")
        cell = p["name"].split("/")[2]
        retrieval = cell.startswith("knn-")
        shapes.add("knn" if retrieval else cell.split("-")[0])
        if not retrieval:
            continue
        fn, ln = _FNLL_RE.search(p["derived"]), _LNLL_RE.search(p["derived"])
        if not fn or not ln:
            raise ValueError(
                f"fig16 retrieval row lacks fused_nll=/lm_nll=: {p!r}")
        fused, lm = float(fn.group(1)), float(ln.group(1))
        if not (fused <= lm + NLL_EPS):
            raise ValueError(
                f"{p['name']}: fused NLL {fused:.4f} does not beat pure-LM "
                f"NLL {lm:.4f} on the memorization corpus — the retrieval "
                "path is not retrieving")
    if shapes < {"lm", "knn"}:
        raise ValueError(
            f"fig16 must cover the lm baseline and a knn-* retrieval row; "
            f"got {sorted(shapes)}")


def smoke() -> None:
    """Tiny interpret-mode run + in-process contract validation."""
    from benchmarks.run import parse_row

    rows = run(n=SMOKE_N, backend="interpret")
    for r in rows:
        print(r, flush=True)
    validate_knn_rows([parse_row(r) for r in rows])
    print("# fig16 smoke: fused-NLL <= pure-LM-NLL gate OK",
          file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for datastore build + search")
    ap.add_argument("--n", type=int, default=2048,
                    help="datastore pairs (interpret runs are capped at "
                         f"{C.INTERPRET_MAX_N})")
    ap.add_argument("--new-tokens", type=int, default=8,
                    help="decode steps per timed generation")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode run, self-validating "
                         "(non-zero exit if fused NLL loses to pure LM)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print("name,us_per_call,derived")
        for row in run(n=args.n, backend=args.backend,
                       new_tokens=args.new_tokens):
            print(row, flush=True)
