"""Fig 5 analogue: construction time at matched recall — GRNND vs the
sequential CPU RNN-Descent baseline (and random init as a floor).

The paper's protocol: fixed search algorithm + search params; each method
tunes construction only.  Derived column: recall@10 and speedup over the
sequential baseline.

Backend selection (the fused propagation-round kernel):

    PYTHONPATH=src python benchmarks/fig5_construction.py --backend pallas

records the fused-kernel construction path.  Off-TPU, "pallas" degrades
to interpret mode (Python-stepped kernels), which is a CORRECTNESS
harness, not a performance mode — the benchmark shrinks the dataset so
the end-to-end run stays tractable, and the row is labeled with the
effective backend.  The numbers that matter for the fused path on real
hardware come from the analytic roofline (benchmarks/roofline.py) and
from a TPU run of this same flag.  See EXPERIMENTS.md §Perf cell F.
"""
from __future__ import annotations

import argparse
import time

if __package__ in (None, ""):  # direct `python benchmarks/fig5_construction.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import grnnd, rnnd_ref, pools


def run(n_seq: int = 2500, backend: str | None = None) -> list[str]:
    """`backend` applies to the GRNND BUILD only (the system under test);
    ground truth and recall evaluation keep the fixed default search path,
    per the paper's protocol."""
    eff, tag = C.resolve_backend(backend)
    if eff == "interpret":
        n_seq = min(n_seq, C.INTERPRET_MAX_N)

    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n_seq).items():
        n = x.shape[0]
        # --- sequential RNN-Descent (paper's CPU baseline) ---
        xs = np.asarray(x)
        t0 = time.perf_counter()
        adj = rnnd_ref.build_graph_ref(xs, s=12, r=24, t1=2, t2=2, seed=0)
        t_seq = time.perf_counter() - t0
        ids_seq = jnp.asarray(rnnd_ref.adjacency_to_pool_arrays(adj, 24))
        r_seq = C.eval_recall(x, ids_seq, q, gt)
        rows.append(C.row(f"fig5/{name}/rnnd-cpu", t_seq,
                          f"recall={r_seq:.3f} speedup=1.0x",
                          bytes_per_vector=C.fp32_bpv(x)))

        # --- GRNND (parallel, disordered; fused round per backend) ---
        # NOTE on this CPU-only container: wall-clock measures TOTAL work
        # on one core; the paper's GPU speedup comes from parallelism.  The
        # architecture-independent metric is the dependency critical path:
        # sequential RNN-Descent = N*T1*T2 ordered vertex updates, GRNND =
        # T1*T2 rounds of fully independent vertex updates.
        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        with C.backend_scope(backend):
            pool, t_g = C.timed_build(x, cfg)
        r_g = C.eval_recall(x, pool.ids, q, gt)
        path_seq = n * 2 * 2
        path_g = cfg.t1 * cfg.t2
        rows.append(C.row(
            f"fig5/{name}/grnnd{tag}", t_g,
            f"recall={r_g:.3f} cpu1core_speedup={t_seq / t_g:.2f}x "
            f"backend={eff} "
            f"critical_path={path_g} vs_seq={path_seq} "
            f"parallel_depth_ratio={path_seq / path_g:.0f}x",
            bytes_per_vector=C.fp32_bpv(x)))

        # --- random S-NN init (quality floor) ---
        p0 = pools.init_random(jax.random.PRNGKey(2), x, 12, 24)
        r_0 = C.eval_recall(x, p0.ids, q, gt)
        rows.append(C.row(f"fig5/{name}/random-init", 0.0,
                          f"recall={r_0:.3f} speedup=inf",
                          bytes_per_vector=C.fp32_bpv(x)))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for the GRNND build "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--n", type=int, default=2500,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(n_seq=args.n, backend=args.backend):
        print(row, flush=True)
