"""Fig 5 analogue: construction time at matched recall — GRNND vs the
sequential CPU RNN-Descent baseline (and random init as a floor).

The paper's protocol: fixed search algorithm + search params; each method
tunes construction only.  Derived column: recall@10 and speedup over the
sequential baseline.
"""
from __future__ import annotations

import time

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import grnnd, rnnd_ref, pools


def run(n_seq: int = 2500) -> list[str]:
    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n_seq).items():
        n = x.shape[0]
        # --- sequential RNN-Descent (paper's CPU baseline) ---
        xs = np.asarray(x)
        t0 = time.perf_counter()
        adj = rnnd_ref.build_graph_ref(xs, s=12, r=24, t1=2, t2=2, seed=0)
        t_seq = time.perf_counter() - t0
        ids_seq = jnp.asarray(rnnd_ref.adjacency_to_pool_arrays(adj, 24))
        r_seq = C.eval_recall(x, ids_seq, q, gt)
        rows.append(C.row(f"fig5/{name}/rnnd-cpu", t_seq,
                          f"recall={r_seq:.3f} speedup=1.0x"))

        # --- GRNND (parallel, disordered) ---
        # NOTE on this CPU-only container: wall-clock measures TOTAL work
        # on one core; the paper's GPU speedup comes from parallelism.  The
        # architecture-independent metric is the dependency critical path:
        # sequential RNN-Descent = N*T1*T2 ordered vertex updates, GRNND =
        # T1*T2 rounds of fully independent vertex updates.
        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        pool, t_g = C.timed_build(x, cfg)
        r_g = C.eval_recall(x, pool.ids, q, gt)
        path_seq = n * 2 * 2
        path_g = cfg.t1 * cfg.t2
        rows.append(C.row(
            f"fig5/{name}/grnnd", t_g,
            f"recall={r_g:.3f} cpu1core_speedup={t_seq / t_g:.2f}x "
            f"critical_path={path_g} vs_seq={path_seq} "
            f"parallel_depth_ratio={path_seq / path_g:.0f}x"))

        # --- random S-NN init (quality floor) ---
        p0 = pools.init_random(jax.random.PRNGKey(2), x, 12, 24)
        r_0 = C.eval_recall(x, p0.ids, q, gt)
        rows.append(C.row(f"fig5/{name}/random-init", 0.0,
                          f"recall={r_0:.3f} speedup=inf"))
    return rows
