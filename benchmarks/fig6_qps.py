"""Fig 6 analogue: QPS vs recall of the constructed indices.

Fixed construction settings per method; the search parameter (ef) sweeps the
QPS-recall curve with the SAME unified search for every graph.

Query-path configuration (EXPERIMENTS.md §Perf cell E):

    PYTHONPATH=src python benchmarks/fig6_qps.py --backend pallas \
        --visited hashed

`--backend` selects the kernel path of the SEARCH (the fused
`search_expand` kernel; off-TPU "pallas" degrades to interpret mode — a
correctness harness, so the dataset is capped and rows are labeled with
the effective backend).  `--visited` selects the visited-set
representation (dense (Q, N) bitmask vs the O(Q·H) hashed table).  Graph
construction stays on the ambient default path: the graph under test is
identical across query configurations, per the paper's protocol.

`--optimize-layout` adds before/after rows for the post-build layout pass
(core/layout.py, DESIGN.md §10): next to every baseline `grnnd` row, a
`grnnd-opt` row searches the SAME graph after BFS renumbering + detour
pruning to half the pool width — the QPS side of the layout trade (the
bitwise-exact unpruned configuration is covered by tests/test_layout.py;
this row quantifies the speed a caller buys by opting into pruning).
Every fig6 row carries an `opt_layout=` tag (SMOKE_SCHEMA 4) and the
smoke gate requires QPS(optimized) >= QPS(baseline) per (dataset, ef).
"""
from __future__ import annotations

import argparse
import re

if __package__ in (None, ""):  # direct `python benchmarks/fig6_qps.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import grnnd, layout, rnnd_ref
from repro.core.recall import recall_at_k


def run(n: int = 4000, backend: str | None = None, visited: str = "dense",
        visited_cap: int | None = None,
        optimize_layout: bool = False) -> list[str]:
    eff, tag = C.resolve_backend(backend)
    # interpret mode steps the (Q, R) kernel grid from Python once per beam
    # step: shrink vectors/queries/sweep so the end-to-end run stays in
    # minutes (parity with the fast path is asserted by the test tier)
    interp = eff == "interpret"
    nq, repeats, efs = (64, 1, (16, 32)) if interp else (300, 2, (16, 32, 64, 128))
    if interp:
        n = min(n, C.INTERPRET_MAX_N)
    # encode the full query-path configuration in the row name so rows from
    # different runs are never incomparable under the same label
    vtag = "" if visited == "dense" else f"-{visited}"
    if visited == "hashed" and visited_cap is not None:
        vtag += f"-c{visited_cap}"

    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n, nq=nq).items():
        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        pool, _ = C.timed_build(x, cfg)

        opt = opt_tag = None
        if optimize_layout:
            # the QPS-side configuration: BFS renumbering + detour pruning
            # to half the pool width — halves the per-step row-DMA work,
            # which is what the QPS(opt) >= QPS(base) smoke gate measures.
            # (The bitwise-exact unpruned layout is the test tier's job.)
            opt = layout.optimize(x, pool, order="bfs", prune=True,
                                  degree=max(4, cfg.r // 2))
            opt_tag = f"bfs-p{opt.degree}"

        ids_seq = None
        if x.shape[0] <= 3000 and not interp:  # sequential baseline, small n
            adj = rnnd_ref.build_graph_ref(np.asarray(x), s=12, r=24,
                                           t1=2, t2=2, seed=0)
            ids_seq = jnp.asarray(rnnd_ref.adjacency_to_pool_arrays(adj, 24))

        for ef in efs:
            res, qps = C.timed_search(x, pool.ids, q, ef=ef, repeats=repeats,
                                      backend=backend, visited=visited,
                                      visited_cap=visited_cap)
            rec = recall_at_k(res.ids, gt)
            rows.append(C.row(f"fig6/{name}/grnnd{tag}{vtag}/ef{ef}",
                              1.0 / qps, f"recall={rec:.3f} qps={qps:.0f}",
                              bytes_per_vector=C.fp32_bpv(x),
                              opt_layout="none"))
            if opt is not None:
                res_o, qps_o = C.timed_search(
                    opt.x, opt.graph_ids, q, ef=ef, repeats=repeats,
                    backend=backend, visited=visited,
                    visited_cap=visited_cap, entry=opt.entry,
                    ids_map=opt.inv)
                rec_o = recall_at_k(res_o.ids, gt)
                rows.append(C.row(
                    f"fig6/{name}/grnnd-opt{tag}{vtag}/ef{ef}", 1.0 / qps_o,
                    f"recall={rec_o:.3f} qps={qps_o:.0f}",
                    bytes_per_vector=C.fp32_bpv(x), opt_layout=opt_tag))
            if ids_seq is not None:
                res2, qps2 = C.timed_search(x, ids_seq, q, ef=ef,
                                            repeats=repeats, backend=backend,
                                            visited=visited,
                                            visited_cap=visited_cap)
                rec2 = recall_at_k(res2.ids, gt)
                rows.append(C.row(f"fig6/{name}/rnnd-cpu{tag}{vtag}/ef{ef}",
                                  1.0 / qps2,
                                  f"recall={rec2:.3f} qps={qps2:.0f}",
                                  bytes_per_vector=C.fp32_bpv(x),
                                  opt_layout="none"))
    return rows


_QPS_RE = re.compile(r"(?:^|\s)qps=(\S+)")


def validate_layout_rows(parsed: list[dict]) -> None:
    """SMOKE_SCHEMA 4 gate (benchmarks/run.py): every fig6 row carries an
    `opt_layout=` tag, and every optimized row beats (or ties) its baseline
    partner's QPS — "optimized index => identical results, higher QPS" is
    the whole point of the layout pass, so a regression here fails the
    build instead of silently landing in the trajectory."""
    fig6 = [p for p in parsed if p["name"].startswith("fig6/")]
    by_name = {}
    for p in fig6:
        if not p.get("opt_layout"):
            raise ValueError(f"fig6 row lacks an opt_layout= tag: "
                             f"{p['name']!r}")
        m = _QPS_RE.search(p["derived"])
        if not m:
            raise ValueError(f"fig6 row lacks a qps= field: {p['name']!r}")
        by_name[p["name"]] = float(m.group(1))
    opt_rows = [p for p in fig6 if p["opt_layout"] != "none"]
    if not any(p["opt_layout"] == "none" for p in fig6):
        raise ValueError("fig6 has no baseline (opt_layout=none) rows")
    for p in opt_rows:
        base_name = p["name"].replace("/grnnd-opt", "/grnnd", 1)
        if base_name == p["name"] or base_name not in by_name:
            raise ValueError(f"optimized fig6 row {p['name']!r} has no "
                             f"baseline partner {base_name!r}")
        q_opt, q_base = by_name[p["name"]], by_name[base_name]
        if q_opt < q_base:
            raise ValueError(
                f"layout regression: QPS(optimized)={q_opt:.0f} < "
                f"QPS(baseline)={q_base:.0f} for {p['name']!r}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for the SEARCH (default: current "
                         "REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--visited", default="dense",
                    choices=["dense", "hashed"],
                    help="visited-set representation of the search")
    ap.add_argument("--visited-cap", type=int, default=None,
                    help="hashed-table slots per query "
                         "(default: core.search.default_visited_cap(ef))")
    ap.add_argument("--n", type=int, default=4000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    ap.add_argument("--optimize-layout", action="store_true",
                    help="add before/after rows for the post-build layout "
                         "pass (BFS renumbering + detour pruning to half "
                         "degree, core/layout.py)")
    args = ap.parse_args()
    if args.visited_cap is not None and args.visited != "hashed":
        ap.error("--visited-cap only applies with --visited hashed")
    print("name,us_per_call,derived")
    for row in run(n=args.n, backend=args.backend, visited=args.visited,
                   visited_cap=args.visited_cap,
                   optimize_layout=args.optimize_layout):
        print(row, flush=True)
