"""Fig 6 analogue: QPS vs recall of the constructed indices.

Fixed construction settings per method; the search parameter (ef) sweeps the
QPS-recall curve with the SAME unified search for every graph.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import grnnd, rnnd_ref


def run(n: int = 4000) -> list[str]:
    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n).items():
        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        pool, _ = C.timed_build(x, cfg)

        ids_seq = None
        if x.shape[0] <= 3000:  # sequential baseline only at small n
            adj = rnnd_ref.build_graph_ref(np.asarray(x), s=12, r=24,
                                           t1=2, t2=2, seed=0)
            ids_seq = jnp.asarray(rnnd_ref.adjacency_to_pool_arrays(adj, 24))

        for ef in (16, 32, 64, 128):
            res, qps = C.timed_search(x, pool.ids, q, ef=ef, repeats=2)
            from repro.core.recall import recall_at_k
            rec = recall_at_k(res.ids, gt)
            rows.append(C.row(f"fig6/{name}/grnnd/ef{ef}", 1.0 / qps,
                              f"recall={rec:.3f} qps={qps:.0f}"))
            if ids_seq is not None:
                res2, qps2 = C.timed_search(x, ids_seq, q, ef=ef, repeats=2)
                rec2 = recall_at_k(res2.ids, gt)
                rows.append(C.row(f"fig6/{name}/rnnd-cpu/ef{ef}", 1.0 / qps2,
                                  f"recall={rec2:.3f} qps={qps2:.0f}"))
    return rows
