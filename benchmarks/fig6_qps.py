"""Fig 6 analogue: QPS vs recall of the constructed indices.

Fixed construction settings per method; the search parameter (ef) sweeps the
QPS-recall curve with the SAME unified search for every graph.

Query-path configuration (EXPERIMENTS.md §Perf cell E):

    PYTHONPATH=src python benchmarks/fig6_qps.py --backend pallas \
        --visited hashed

`--backend` selects the kernel path of the SEARCH (the fused
`search_expand` kernel; off-TPU "pallas" degrades to interpret mode — a
correctness harness, so the dataset is capped and rows are labeled with
the effective backend).  `--visited` selects the visited-set
representation (dense (Q, N) bitmask vs the O(Q·H) hashed table).  Graph
construction stays on the ambient default path: the graph under test is
identical across query configurations, per the paper's protocol.
"""
from __future__ import annotations

import argparse

if __package__ in (None, ""):  # direct `python benchmarks/fig6_qps.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import grnnd, rnnd_ref
from repro.core.recall import recall_at_k


def run(n: int = 4000, backend: str | None = None, visited: str = "dense",
        visited_cap: int | None = None) -> list[str]:
    eff, tag = C.resolve_backend(backend)
    # interpret mode steps the (Q, R) kernel grid from Python once per beam
    # step: shrink vectors/queries/sweep so the end-to-end run stays in
    # minutes (parity with the fast path is asserted by the test tier)
    interp = eff == "interpret"
    nq, repeats, efs = (64, 1, (16, 32)) if interp else (300, 2, (16, 32, 64, 128))
    if interp:
        n = min(n, C.INTERPRET_MAX_N)
    # encode the full query-path configuration in the row name so rows from
    # different runs are never incomparable under the same label
    vtag = "" if visited == "dense" else f"-{visited}"
    if visited == "hashed" and visited_cap is not None:
        vtag += f"-c{visited_cap}"

    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n, nq=nq).items():
        cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                pairs_per_vertex=24)
        pool, _ = C.timed_build(x, cfg)

        ids_seq = None
        if x.shape[0] <= 3000 and not interp:  # sequential baseline, small n
            adj = rnnd_ref.build_graph_ref(np.asarray(x), s=12, r=24,
                                           t1=2, t2=2, seed=0)
            ids_seq = jnp.asarray(rnnd_ref.adjacency_to_pool_arrays(adj, 24))

        for ef in efs:
            res, qps = C.timed_search(x, pool.ids, q, ef=ef, repeats=repeats,
                                      backend=backend, visited=visited,
                                      visited_cap=visited_cap)
            rec = recall_at_k(res.ids, gt)
            rows.append(C.row(f"fig6/{name}/grnnd{tag}{vtag}/ef{ef}",
                              1.0 / qps, f"recall={rec:.3f} qps={qps:.0f}",
                              bytes_per_vector=C.fp32_bpv(x)))
            if ids_seq is not None:
                res2, qps2 = C.timed_search(x, ids_seq, q, ef=ef,
                                            repeats=repeats, backend=backend,
                                            visited=visited,
                                            visited_cap=visited_cap)
                rec2 = recall_at_k(res2.ids, gt)
                rows.append(C.row(f"fig6/{name}/rnnd-cpu{tag}{vtag}/ef{ef}",
                                  1.0 / qps2,
                                  f"recall={rec2:.3f} qps={qps2:.0f}",
                                  bytes_per_vector=C.fp32_bpv(x)))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for the SEARCH (default: current "
                         "REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--visited", default="dense",
                    choices=["dense", "hashed"],
                    help="visited-set representation of the search")
    ap.add_argument("--visited-cap", type=int, default=None,
                    help="hashed-table slots per query "
                         "(default: core.search.default_visited_cap(ef))")
    ap.add_argument("--n", type=int, default=4000,
                    help="vectors per dataset (interpret runs are capped "
                         f"at {C.INTERPRET_MAX_N})")
    args = ap.parse_args()
    if args.visited_cap is not None and args.visited != "hashed":
        ap.error("--visited-cap only applies with --visited hashed")
    print("name,us_per_call,derived")
    for row in run(n=args.n, backend=args.backend, visited=args.visited,
                   visited_cap=args.visited_cap):
        print(row, flush=True)
