"""Fig 7 analogue: candidate update strategy ablation —
ascending vs descending vs disordered (the paper's core claim: disordered
balances construction time and accuracy; ascending risks convergence traps;
descending explores but costs more).
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import grnnd


def run(n: int = 4000) -> list[str]:
    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n).items():
        for order in ("ascending", "descending", "disordered"):
            cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6,
                                    pairs_per_vertex=24, order=order)
            pool, t = C.timed_build(x, cfg)
            rec = C.eval_recall(x, pool.ids, q, gt)
            deg = float(pool.degree().mean())
            rows.append(C.row(f"fig7/{name}/{order}", t,
                              f"recall={rec:.3f} mean_degree={deg:.1f}"))
    return rows
