"""Fig 8 analogue: reverse-edge sampling ratio (rho) sweep.

The paper's claim: low rho is fast but loses connectivity/recall; high rho
costs time with diminishing returns; rho ~= 0.6 is the sweet spot.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import grnnd


def run(n: int = 4000) -> list[str]:
    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n).items():
        for rho in (0.1, 0.3, 0.6, 0.8, 1.0):
            cfg = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=rho,
                                    pairs_per_vertex=24)
            pool, t = C.timed_build(x, cfg)
            rec = C.eval_recall(x, pool.ids, q, gt)
            rows.append(C.row(f"fig8/{name}/rho{rho}", t,
                              f"recall={rec:.3f}"))
    return rows
