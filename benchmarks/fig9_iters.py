"""Fig 9 analogue: outer (T1) x inner (T2) iteration sensitivity.

Paper's claim: T2 gains are dimension-dependent (high-dim needs deeper
refinement); T1 grows cost roughly linearly and matters most for high-dim.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import grnnd


def run(n: int = 3000) -> list[str]:
    rows = []
    for name, (x, q, gt) in C.bench_datasets(n=n).items():
        for t1 in (1, 2, 4):
            for t2 in (1, 2, 4, 8):
                cfg = grnnd.GRNNDConfig(s=12, r=24, t1=t1, t2=t2, rho=0.6,
                                        pairs_per_vertex=24)
                pool, t = C.timed_build(x, cfg)
                rec = C.eval_recall(x, pool.ids, q, gt)
                rows.append(C.row(f"fig9/{name}/t1={t1}/t2={t2}", t,
                                  f"recall={rec:.3f}"))
    return rows
