"""Render EXPERIMENTS.md tables from results/dryrun*/ JSON artifacts.

    PYTHONPATH=src python -m benchmarks.make_report [--dir results/dryrun_final]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.roofline import analyze
from repro.configs import list_archs
from repro.configs.base import SHAPES


def dryrun_table(rdir: pathlib.Path, mesh: str) -> str:
    # memory_analysis() values are already PER-DEVICE (SPMD module)
    lines = [
        "| arch | shape | status | compile_s | arg GiB/dev | temp GiB/dev | HLO coll GiB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    archs = list_archs() + ["grnnd-ann"]
    shapes = list(SHAPES) + ["build_1m_d128", "build_1m_d960"]
    for arch in archs:
        for shape in shapes:
            f = rdir / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                continue
            d = json.loads(f.read_text())
            if d["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skipped ({d['reason'][:40]}...) | | | | |")
                continue
            if d["status"] != "ok":
                lines.append(f"| {arch} | {shape} | FAILED | | | | |")
                continue
            mem = d["memory"]
            lines.append(
                f"| {arch} | {shape} | ok | {d.get('compile_s','')} | "
                f"{mem['argument_size_bytes']/2**30:.3f} | "
                f"{mem['temp_size_bytes']/2**30:.3f} | "
                f"{d['collectives']['total_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def roofline_table(results_dir: str) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/dev | useful ratio | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "collective": "reshard: keep tokens data-sharded / explicit a2a",
        "memory": "fuse + donate buffers; cut remat width; bf16 more tensors",
        "compute": "larger per-chip batch or fewer chips (already compute-bound)",
    }
    for r in analyze(results_dir):
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | | | {r.get('reason','')[:40]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['model_flops_per_device']:.2e} | "
            f"{r['useful_ratio']:.2f} | {levers[r['dominant']]} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_final")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rdir = pathlib.Path(args.dir)
    print("## Dry-run table\n")
    print(dryrun_table(rdir, args.mesh))
    print("\n## Roofline table\n")
    print(roofline_table(args.dir))


if __name__ == "__main__":
    main()
