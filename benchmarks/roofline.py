"""Roofline analysis (deliverable g): three terms per (arch x shape) from
the compiled dry-run artifacts in results/dryrun_final/.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

cost_analysis() / the HLO shapes come from the SPMD per-device program, so
no further division by chip count is needed; scan-body undercounting is
already corrected by the dry-run's k=1/k=2 unrolled probes (see dryrun.py).

MODEL_FLOPS uses 6·N_active·T (train) or 2·N_active·T (inference) plus the
attention-context term; the ratio MODEL_FLOPS / HLO_FLOPs measures how much
compiled compute is useful (remat recompute and padding waste push it down;
values > 1 would mean XLA found algebraic savings).
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import get_arch, list_archs
from repro.configs.base import SHAPES
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def model_flops_per_device(arch: str, shape_name: str, n_chips: int) -> float:
    """Useful-math FLOPs per device (param matmuls + attention context)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * n_active * tokens
        # attention context: fwd 4·H·Dh·S_eff per token, x3 for bwd
        flops += 3.0 * _attn_context_flops(cfg, s, tokens)
    elif shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_active * tokens
        flops += _attn_context_flops(cfg, s, tokens)
    else:  # decode: one token each
        tokens = b
        flops = 2.0 * n_active * tokens
        flops += _attn_context_flops(cfg, s, tokens, decode=True)
    return flops / n_chips


def _attn_context_flops(cfg, s, tokens, decode=False) -> float:
    """4·H·Dh·context per token per attention layer (qk^T + att·v)."""
    if not cfg.n_heads:
        return 0.0
    h, dh = cfg.n_heads, cfg.head_dim
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "ssm":
            continue
        if kind == "local" and cfg.window:
            ctx = min(cfg.window, s)
        else:
            ctx = s
        if not decode:
            ctx = ctx / 2.0  # causal average
        total += 4.0 * h * dh * ctx * tokens
    return total


def analyze(results_dir: str = "results/dryrun_final", mesh: str = "single"):
    rows = []
    rdir = pathlib.Path(results_dir)
    cells = [(a, s) for a in list_archs() for s in SHAPES]
    cells += [("grnnd-ann", s) for s in ("build_1m_d128", "build_1m_d960")]
    for arch, shape in cells:
            f = rdir / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                continue
            d = json.loads(f.read_text())
            if d["status"] != "ok":
                rows.append({"arch": arch, "shape": shape,
                             "status": d["status"],
                             "reason": d.get("reason", "")})
                continue
            n_chips = 1
            for v in d["mesh_shape"].values():
                n_chips *= v
            t_comp = d["cost"]["flops"] / PEAK_FLOPS_BF16
            t_mem = d["cost"]["bytes_accessed"] / HBM_BW
            t_coll = d["collectives"]["total_bytes"] / ICI_BW_PER_LINK
            terms = {"compute": t_comp, "memory": t_mem,
                     "collective": t_coll}
            dominant = max(terms, key=terms.get)
            mf = (model_flops_per_device(arch, shape, n_chips)
                  if arch != "grnnd-ann" else 0.0)
            rows.append({
                "arch": arch, "shape": shape, "status": "ok",
                "t_compute_s": t_comp, "t_memory_s": t_mem,
                "t_collective_s": t_coll, "dominant": dominant,
                "model_flops_per_device": mf,
                "useful_ratio": mf / max(d["cost"]["flops"], 1.0),
                "bound_s": max(terms.values()),
                "roofline_frac": (t_comp / max(terms.values())
                                  if max(terms.values()) > 0 else 0.0),
            })
    return rows


def grnnd_round_model(d: int, n: int = 1_000_000, r: int = 32,
                      p: int = 32, bytes_per_dim: float = 4.0) -> dict:
    """Analytic roofline terms for ONE propagation round, fused vs unfused.

    Unfused (the pre-fusion XLA pipeline, EXPERIMENTS.md §Perf cell C):
    the two (N·P, D) neighbor-vector gathers are materialized in HBM —
    2·N·P·D reads out of x, 2·N·P·D writes, then 2·N·P·D re-reads by
    rowwise_sqdist: ~24·N·P·D bytes of fp32 traffic.

    Fused (kernels/rng_round.py): each pool vector is DMA'd into VMEM once
    per vertex regardless of how many sampled pairs touch it — N·R·D reads
    — and all pair math stays on-chip; only the (P,)/(R,) request/kill
    outputs return to HBM.

    FLOPs term: the diff-square-reduce pair math (3·N·P·D) plus the two
    one-hot selection matmuls the fused kernel feeds the MXU (4·N·P·R·D).

    `bytes_per_dim` is the precision ladder's storage width (DESIGN.md §8:
    4.0 fp32, 2.0 bf16, 1.0 int8): it scales exactly the x-row traffic —
    the dominant term of the fused round — while pools/samples/outputs
    stay fp32/int32.
    """
    small_io = n * (2 * r + 2 * p + 3 * p + r) * 4     # pools, samples, outs
    fused_bytes = int(n * r * d * bytes_per_dim) + small_io
    unfused_bytes = int(6 * n * p * d * bytes_per_dim) + small_io
    flops = 3.0 * n * p * d + 4.0 * n * p * r * d
    t_mem_fused = fused_bytes / HBM_BW
    t_mem_unfused = unfused_bytes / HBM_BW
    t_comp = flops / PEAK_FLOPS_BF16
    return {
        "t_compute_s": t_comp,
        "t_mem_fused_s": t_mem_fused,
        "t_mem_unfused_s": t_mem_unfused,
        "traffic_cut": unfused_bytes / fused_bytes,
        "bound_fused_s": max(t_comp, t_mem_fused),
        "bound_unfused_s": max(t_comp, t_mem_unfused),
        "dominant": "compute" if t_comp > t_mem_fused else "memory",
    }


def grnnd_round_rows() -> list[str]:
    """Fused-round speedup rows (recorded alongside the dry-run cells).

    One row per precision rung (DESIGN.md §8): the fused round is memory-
    bound at every realistic D, so bf16/int8 storage converts its
    bytes/vector cut almost 1:1 into round-time cut — the analytic
    counterpart of benchmarks/fig11_precision.py.
    """
    out = []
    for shape, d in (("build_1m_d128", 128), ("build_1m_d960", 960)):
        base = grnnd_round_model(d)
        for prec, bpd in (("fp32", 4.0), ("bf16", 2.0), ("int8", 1.0)):
            m = grnnd_round_model(d, bytes_per_dim=bpd)
            derived = (f"dom={m['dominant']}"
                       f" comp={m['t_compute_s']*1e3:.2f}ms"
                       f" mem={m['t_mem_fused_s']*1e3:.2f}ms"
                       f" mem_unfused={m['t_mem_unfused_s']*1e3:.2f}ms"
                       f" traffic_cut={m['traffic_cut']:.1f}x"
                       f" round_speedup="
                       f"{m['bound_unfused_s']/m['bound_fused_s']:.1f}x"
                       f" vs_fp32="
                       f"{base['bound_fused_s']/m['bound_fused_s']:.2f}x")
            suffix = "" if prec == "fp32" else f"-{prec}"
            out.append(
                f"roofline/grnnd-round-fused{suffix}/{shape},"
                f"{m['bound_fused_s']*1e6:.1f},{derived}"
                f" precision={prec} bpv={bpd * d:.1f}")
    return out


def grnnd_expand_layout_model(d: int, *, q: int = 1024, r: int = 32,
                              degree: int = 24, locality: float = 0.35,
                              bytes_per_dim: float = 4.0,
                              trans: int = 512) -> dict:
    """Analytic DMA model of ONE search-expansion step, raw vs optimized
    layout (core/layout.py, DESIGN.md §10).

    Per query the fused kernel (kernels/search_expand.py) DMAs the
    selected vertex's neighbor rows: R row reads of d·bytes_per_dim bytes
    each, at effectively random row addresses — every read pays the full
    HBM transaction granularity `trans` (~a 512 B burst).  The optimized
    layout cuts this two ways:

      * packing: only `degree` (the packed D ≤ R) rows exist per vertex —
        sentinel tail slots re-read row 0's page, which is free;
      * renumbering: a `locality` fraction of neighbor rows land adjacent
        to rows fetched by the same step (BFS levels are contiguous), so
        their bursts coalesce and pay row bytes instead of a full
        transaction.

    `locality` = 0.35 is the measured EXPERIMENTS.md §L1 figure for
    BFS-from-medoid at reproduction scale; the model is deliberately
    first-order (no cache reuse across queries) — it bounds the win the
    fig6 wall-clock rows then measure end to end.
    """
    row_bytes = d * bytes_per_dim
    per_read_raw = max(row_bytes, trans)
    base_bytes = q * r * per_read_raw
    opt_bytes = q * degree * (locality * row_bytes
                              + (1.0 - locality) * per_read_raw)
    return {
        "t_mem_base_s": base_bytes / HBM_BW,
        "t_mem_opt_s": opt_bytes / HBM_BW,
        "dma_cut": base_bytes / opt_bytes,
    }


def grnnd_expand_layout_rows() -> list[str]:
    """The layout pass's roofline entry: step-time bound before/after the
    packed + renumbered adjacency, per corpus shape (ISSUE 6)."""
    out = []
    for shape, d in (("search_1m_d128", 128), ("search_1m_d960", 960)):
        m = grnnd_expand_layout_model(d)
        derived = (f"dom=memory"
                   f" mem_base={m['t_mem_base_s']*1e6:.1f}us"
                   f" mem_opt={m['t_mem_opt_s']*1e6:.1f}us"
                   f" dma_cut={m['dma_cut']:.2f}x"
                   f" degree=24of32 locality=0.35")
        out.append(
            f"roofline/grnnd-expand-layout/{shape},"
            f"{m['t_mem_opt_s']*1e6:.1f},{derived}"
            f" precision=fp32 bpv={4.0 * d:.1f} opt_layout=bfs-d24")
    return out


def run() -> list[str]:
    out = grnnd_round_rows()
    out += grnnd_expand_layout_rows()
    for r in analyze():
        name = f"roofline/{r['arch']}/{r['shape']}"
        # LLM dry-run cells have no ANN vector storage: precision/bpv are
        # the schema-mandated placeholders (fp32 compute, no per-vector
        # bytes), kept so every smoke row validates uniformly
        if r["status"] != "ok":
            out.append(f"{name},0.0,{r['status']}:{r.get('reason','')[:40]}"
                       f" precision=fp32 bpv=0.0")
            continue
        derived = (f"dom={r['dominant']}"
                   f" comp={r['t_compute_s']*1e3:.2f}ms"
                   f" mem={r['t_memory_s']*1e3:.2f}ms"
                   f" coll={r['t_collective_s']*1e3:.2f}ms"
                   f" useful={r['useful_ratio']:.2f}"
                   f" frac={r['roofline_frac']:.3f}")
        out.append(f"{name},{r['bound_s']*1e6:.1f},{derived}"
                   f" precision=fp32 bpv=0.0")
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
