"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figures 5-9 reproduce the paper's
experiment families at reduced CPU scale; fig10 measures the dynamic index
under churn (beyond the paper); `roofline` reads the dry-run artifacts (run
`python -m repro.launch.dryrun --all` first to refresh).

``--smoke`` is the CI perf-trajectory seed (ISSUE 3): a tiny-preset,
interpret-mode-kernel run of the representative families (fig5 build path,
fig6 query path, fig10 dynamic path, analytic roofline) written to a JSON
artifact and validated against the row schema — so every PR leaves a
comparable breadcrumb and a schema drift fails the build instead of
silently corrupting the trajectory.

    PYTHONPATH=src python -m benchmarks.run [--only fig5 roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke --out BENCH_smoke.json
    PYTHONPATH=src python -m benchmarks.run --check BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time

ALL = ("fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
       "fig13", "fig14", "fig15", "fig16", "roofline")

# the artifact contract: bump ONLY with a matching update to every consumer
# of the perf trajectory (EXPERIMENTS.md §Tables tooling)
# schema 2: rows carry `precision=` and `bpv=` (bytes/vector of the
# traversal tier) so the trajectory can distinguish dtype regressions from
# algorithmic ones (ISSUE 4)
# schema 3: filtered-search rows (fig12) carry `selectivity=` — validated
# as a float in [0, 1] wherever present, required on every fig12 row —
# so the trajectory can slice the filtered cost curve per selectivity
# (ISSUE 5)
# schema 4: graph-layout rows carry `opt_layout=` (core/layout.py): "none"
# for the raw pool layout or the ordering(+pruned-degree) tag of an
# optimized index — required on every fig6 row, and the fig6 validator
# gates QPS(optimized) >= QPS(baseline) per (dataset, ef) (ISSUE 6)
# schema 5: corpus-sharded rows (fig13) carry `corpus_shards=` (int >= 1,
# core/corpus_shard.py) — required on every fig13 row, and the fig13
# validator gates the recall floor plus per-shard memory < replicated
# wherever S > 1 (the N-ceiling claim, ISSUE 7)
# schema 6: serving rows (fig14, serve/ann_engine.py) carry
# `p50_ms=`/`p99_ms=`/`qps=` (nearest-rank per-request latency + achieved
# throughput) — required on every fig14 row by the fig14 validator; the
# family gate now also requires at least one SUCCESSFUL row per family
# (a family that silently stops emitting rows fails, not just schema
# drift), and `--check FILE` re-validates an existing artifact so CI can
# gate the uploaded file independently of the process that wrote it
# (ISSUE 8)
# schema 7: tiered-storage rows (fig15, core/vecstore.py HostTier) carry
# `tier=` ("device" or "host" — where the fp32 rescore tier lives) —
# validated wherever present, required on every fig15 row by the fig15
# validator, which also gates zero device-resident rescore bytes and
# bitwise host/device parity on every host row (ISSUE 9)
# schema 8: kNN-LM decode rows (fig16, retrieval/knn_lm.py +
# serve/engine.py) carry `tok_s=` (end-to-end generate throughput) and,
# on retrieval rows, `fused_nll=`/`lm_nll=` (teacher-forced NLL on the
# memorization corpus) — lifted wherever present as non-negative floats;
# the fig16 validator REQUIRES the lm baseline + a knn-* retrieval row
# and gates fused_nll <= lm_nll (the decode-time retrieval hook provably
# retrieves, ISSUE 10)
SMOKE_SCHEMA = 8
SMOKE_N = 192
_ROW_RE = re.compile(r"^(fig\d+|roofline)/[\w./@+-]+$")
_PRECISIONS = ("fp32", "bf16", "int8")
_PREC_RE = re.compile(r"(?:^|\s)precision=(\S+)")
_BPV_RE = re.compile(r"(?:^|\s)bpv=(\S+)")
_SEL_RE = re.compile(r"(?:^|\s)selectivity=(\S+)")
_OPT_RE = re.compile(r"(?:^|\s)opt_layout=([\w.-]+)")
_CS_RE = re.compile(r"(?:^|\s)corpus_shards=(\S+)")
_P50_RE = re.compile(r"(?:^|\s)p50_ms=(\S+)")
_P99_RE = re.compile(r"(?:^|\s)p99_ms=(\S+)")
_QPS_RE = re.compile(r"(?:^|\s)qps=(\S+)")
_TIER_RE = re.compile(r"(?:^|\s)tier=(\S+)")
_TIERS = ("device", "host")
_FNLL_RE = re.compile(r"(?:^|\s)fused_nll=(\S+)")
_LNLL_RE = re.compile(r"(?:^|\s)lm_nll=(\S+)")
# families the smoke artifact must always cover (one per serving surface)
SMOKE_FAMILIES = ("fig5", "fig6", "fig10", "fig11", "fig12", "fig13",
                  "fig14", "fig15", "fig16", "roofline")


def _module(name: str):
    if name == "fig5":
        from benchmarks import fig5_construction as m
    elif name == "fig6":
        from benchmarks import fig6_qps as m
    elif name == "fig7":
        from benchmarks import fig7_order as m
    elif name == "fig8":
        from benchmarks import fig8_rho as m
    elif name == "fig9":
        from benchmarks import fig9_iters as m
    elif name == "fig10":
        from benchmarks import fig10_churn as m
    elif name == "fig11":
        from benchmarks import fig11_precision as m
    elif name == "fig12":
        from benchmarks import fig12_filtered as m
    elif name == "fig13":
        from benchmarks import fig13_corpus_sharded as m
    elif name == "fig14":
        from benchmarks import fig14_serving as m
    elif name == "fig15":
        from benchmarks import fig15_tiered as m
    elif name == "fig16":
        from benchmarks import fig16_knn_lm as m
    elif name == "roofline":
        from benchmarks import roofline as m
    else:
        return None
    return m


def parse_row(row: str) -> dict:
    """Split one CSV row into the artifact dict; raises ValueError on drift.

    Schema 2: the derived column must carry `precision=<rung>` and
    `bpv=<float>` (traversal-tier bytes/vector; 0.0 for cells with no
    vector storage, e.g. analytic roofline LLM cells) — both are lifted
    into top-level artifact fields.

    Schema 3: an optional `selectivity=<float>` (filtered-search rows) is
    lifted as well; where present it must parse as a float in [0, 1].
    The fig12 validator additionally REQUIRES it on every fig12 row.

    Schema 4: an optional `opt_layout=<tag>` (graph-layout rows,
    core/layout.py) is lifted; the fig6 validator REQUIRES it on every
    fig6 row and gates QPS(optimized) >= QPS(baseline).

    Schema 5: an optional `corpus_shards=<int>` (corpus-sharded rows,
    core/corpus_shard.py) is lifted; where present it must parse as an
    int >= 1.  The fig13 validator additionally REQUIRES it on every
    fig13 row and gates recall + the per-shard memory reduction.

    Schema 6: optional `p50_ms=`/`p99_ms=`/`qps=` (serving rows,
    serve/ann_engine.py) are lifted; where present they must parse as
    non-negative floats.  The fig14 validator additionally REQUIRES all
    three on every fig14 row and gates p50 <= p99 + completion.

    Schema 7: an optional `tier=<placement>` (tiered-storage rows,
    core/vecstore.py HostTier) is lifted; where present it must be
    "device" or "host".  The fig15 validator additionally REQUIRES it on
    every fig15 row and gates the placement + parity contract.

    Schema 8: optional `fused_nll=`/`lm_nll=` (kNN-LM decode rows,
    retrieval/knn_lm.py) are lifted; where present they must parse as
    non-negative floats.  The fig16 validator additionally REQUIRES both
    on every retrieval row and gates fused_nll <= lm_nll.
    """
    parts = row.split(",", 2)
    if len(parts) != 3:
        raise ValueError(f"row is not name,us_per_call,derived: {row!r}")
    name, us, derived = parts
    if not _ROW_RE.match(name):
        raise ValueError(f"row name outside the fig*/roofline namespace: "
                         f"{name!r}")
    prec = _PREC_RE.search(derived)
    bpv = _BPV_RE.search(derived)
    if not prec or prec.group(1) not in _PRECISIONS:
        raise ValueError(f"row lacks a valid precision= field: {row!r}")
    if not bpv:
        raise ValueError(f"row lacks a bpv= field: {row!r}")
    bpv_val = float(bpv.group(1))
    if bpv_val < 0:
        raise ValueError(f"negative bytes/vector: {row!r}")
    sel = _SEL_RE.search(derived)
    sel_val = None
    if sel:
        sel_val = float(sel.group(1))
        if not 0.0 <= sel_val <= 1.0:
            raise ValueError(f"selectivity outside [0, 1]: {row!r}")
    opt = _OPT_RE.search(derived)
    cs = _CS_RE.search(derived)
    cs_val = None
    if cs:
        cs_val = int(cs.group(1))
        if cs_val < 1:
            raise ValueError(f"corpus_shards below 1: {row!r}")
    serving = {}
    for field, rx in (("p50_ms", _P50_RE), ("p99_ms", _P99_RE),
                      ("qps", _QPS_RE)):
        m = rx.search(derived)
        serving[field] = None
        if m:
            serving[field] = float(m.group(1))
            if serving[field] < 0:
                raise ValueError(f"negative {field}: {row!r}")
    tier = _TIER_RE.search(derived)
    tier_val = None
    if tier:
        tier_val = tier.group(1)
        if tier_val not in _TIERS:
            raise ValueError(f"tier outside {_TIERS}: {row!r}")
    nlls = {}
    for field, rx in (("fused_nll", _FNLL_RE), ("lm_nll", _LNLL_RE)):
        m = rx.search(derived)
        nlls[field] = None
        if m:
            nlls[field] = float(m.group(1))
            if nlls[field] < 0:
                raise ValueError(f"negative {field}: {row!r}")
    return {"name": name, "us_per_call": float(us), "derived": derived,
            "precision": prec.group(1), "bytes_per_vector": bpv_val,
            "selectivity": sel_val,
            "opt_layout": opt.group(1) if opt else None,
            "corpus_shards": cs_val, "tier": tier_val, **serving, **nlls}


def validate_rows(parsed: list[dict]) -> None:
    """Schema gate for the smoke artifact: every family present WITH at
    least one successful row (a family that silently stops emitting rows
    must fail, not just one that crashes), no ERROR rows (a crashed
    benchmark must fail CI, not upload a hole), and the per-family
    validators (fig6 layout, fig11 precision ladder, fig12 filtered,
    fig13 corpus-sharded, fig14 serving, fig15 tiered placement, fig16
    kNN-LM decode)."""
    for fam in SMOKE_FAMILIES:
        ok = [p for p in parsed
              if p["name"].startswith(fam + "/")
              and "/ERROR" not in p["name"]]
        if not ok:
            raise ValueError(
                f"smoke artifact has no successful {fam!r} rows")
    errors = [p["name"] for p in parsed if "/ERROR" in p["name"]]
    if errors:
        raise ValueError(f"benchmark families crashed: {errors}")
    from benchmarks.fig6_qps import validate_layout_rows
    from benchmarks.fig11_precision import validate_precision_rows
    from benchmarks.fig12_filtered import validate_filtered_rows
    from benchmarks.fig13_corpus_sharded import validate_corpus_rows
    from benchmarks.fig14_serving import validate_serving_rows
    from benchmarks.fig15_tiered import validate_tiered_rows
    from benchmarks.fig16_knn_lm import validate_knn_rows
    validate_layout_rows(parsed)
    validate_precision_rows(parsed)
    validate_filtered_rows(parsed)
    validate_corpus_rows(parsed)
    validate_serving_rows(parsed)
    validate_tiered_rows(parsed)
    validate_knn_rows(parsed)


def run_smoke(out_path: str) -> None:
    """Tiny-preset interpret-kernel run -> validated JSON artifact."""
    rows: list[str] = []
    calls = (
        ("fig5", lambda m: m.run(n_seq=SMOKE_N, backend="interpret")),
        ("fig6", lambda m: m.run(n=SMOKE_N, backend="interpret",
                                 optimize_layout=True)),
        ("fig10", lambda m: m.run(n=SMOKE_N, backend="interpret")),
        ("fig11", lambda m: m.run(n=SMOKE_N, backend="interpret")),
        ("fig12", lambda m: m.run(n=SMOKE_N, backend="interpret")),
        ("fig13", lambda m: m.run(n=SMOKE_N, backend="interpret")),
        ("fig14", lambda m: m.run(n=SMOKE_N, backend="interpret")),
        ("fig15", lambda m: m.run(n=SMOKE_N, backend="interpret")),
        ("fig16", lambda m: m.run(n=SMOKE_N, backend="interpret")),
        ("roofline", lambda m: m.run()),
    )
    for name, call in calls:
        t0 = time.time()
        try:
            rows.extend(call(_module(name)))
        except Exception as e:
            # placeholder precision/bpv keep the row parseable so the
            # failure surfaces as "families crashed", not schema noise
            rows.append(f"{name}/ERROR,0.0,{type(e).__name__}:{e}"
                        f" precision=fp32 bpv=0.0")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)

    parsed = [parse_row(r) for r in rows]
    payload = {"schema": SMOKE_SCHEMA, "n": SMOKE_N, "backend": "interpret",
               "rows": parsed}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {len(parsed)} rows -> {out_path}", file=sys.stderr)
    validate_rows(parsed)  # raises (non-zero exit) on drift


def check_artifact(path: str) -> None:
    """Re-validate an EXISTING smoke artifact from disk: schema version,
    row contract, and family completeness.  This is the CI gate run as a
    separate step from the process that wrote the file — `run_smoke`'s
    in-process validation cannot catch an artifact that was uploaded
    stale, truncated, or from a diverged writer."""
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != SMOKE_SCHEMA:
        raise ValueError(f"{path}: schema {payload.get('schema')!r} != "
                         f"expected {SMOKE_SCHEMA}")
    rows = payload.get("rows", [])
    if not rows:
        raise ValueError(f"{path}: artifact has no rows")
    # re-parse from the raw columns, not the stored lifted fields: the
    # artifact must revalidate from first principles
    parsed = [parse_row(f"{p['name']},{p['us_per_call']},{p['derived']}")
              for p in rows]
    validate_rows(parsed)
    print(f"# {path}: schema {SMOKE_SCHEMA}, {len(parsed)} rows, "
          f"all {len(SMOKE_FAMILIES)} families present", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {ALL}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-preset interpret-mode run -> JSON artifact "
                         "(the CI perf-trajectory seed)")
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="smoke artifact path (only with --smoke)")
    ap.add_argument("--check", default=None, metavar="FILE",
                    help="re-validate an existing smoke artifact (schema "
                         "+ family completeness) and exit; the CI gate "
                         "step (runs nothing)")
    args = ap.parse_args()

    if args.check:
        if args.smoke or args.only:
            ap.error("--check runs nothing; drop --smoke/--only")
        check_artifact(args.check)
        return
    if args.smoke:
        if args.only:
            ap.error("--only does not apply to --smoke (fixed family set)")
        run_smoke(args.out)
        return

    which = args.only or ALL
    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        m = _module(name)
        if m is None:
            print(f"# unknown benchmark {name}", file=sys.stderr)
            continue
        try:
            for row in m.run():
                print(row, flush=True)
        except Exception as e:  # keep the harness going
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
