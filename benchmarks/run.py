"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figures 5-9 reproduce the paper's
experiment families at reduced CPU scale; `roofline` reads the dry-run
artifacts (run `python -m repro.launch.dryrun --all` first to refresh).
"""
from __future__ import annotations

import argparse
import sys
import time

ALL = ("fig5", "fig6", "fig7", "fig8", "fig9", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {ALL}")
    args = ap.parse_args()
    which = args.only or ALL

    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        if name == "fig5":
            from benchmarks import fig5_construction as m
        elif name == "fig6":
            from benchmarks import fig6_qps as m
        elif name == "fig7":
            from benchmarks import fig7_order as m
        elif name == "fig8":
            from benchmarks import fig8_rho as m
        elif name == "fig9":
            from benchmarks import fig9_iters as m
        elif name == "roofline":
            from benchmarks import roofline as m
        else:
            print(f"# unknown benchmark {name}", file=sys.stderr)
            continue
        try:
            for row in m.run():
                print(row, flush=True)
        except Exception as e:  # keep the harness going
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
