"""kNN-LM: GRNND as the retrieval substrate for a language model.

The first whole-system scenario on the production stack (DESIGN.md §14):
trains a tiny LM, indexes its hidden states in a `DynamicDatastore` —
a `core.dynamic.DynamicIndex` with int8 traversal + fp32 rescore — and
serves retrieval-fused decoding through `ServeEngine`:

  * every decode step's post-`final_norm` hidden state queries the index
    through the fused `search_expand` kernels (`logit_hook`);
  * the generation's own (hidden, sampled-token) pairs stream back INTO
    the index while it decodes (`token_hook` -> batched insert +
    localized refinement — the dynamic-index workload, for real);
  * fused vs pure-LM NLL is compared on data overlapping the datastore
    (the classic kNN-LM memorization win);
  * optionally the retrieval rides the continuous-batching AnnEngine
    (`--engine`: per-step latency percentiles from the same scheduler
    that serves every other ANN workload) and the fp32 rescore tier can
    be pinned host-side (`--tier host`).

    PYTHONPATH=src python examples/knn_lm.py [--tier host] [--engine]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.grnnd import GRNNDConfig
from repro.data import pipeline as PIPE
from repro.models import transformer as T
from repro.retrieval import knn_lm
from repro.serve.engine import ServeEngine
from repro.launch.train import train


def nll(log_probs, targets):
    lsm = jax.nn.log_softmax(log_probs, -1)
    return float(-jnp.take_along_axis(lsm, targets[:, None], axis=-1).mean())


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--precision", default="int8",
                    choices=["fp32", "bf16", "int8"],
                    help="datastore traversal tier (int8/bf16 rescore "
                         "against fp32)")
    ap.add_argument("--tier", default="device", choices=["device", "host"],
                    help="fp32 rescore-tier placement (host needs a "
                         "quantized traversal tier)")
    ap.add_argument("--engine", action="store_true",
                    help="route retrieval through the continuous-batching "
                         "AnnEngine (reports per-step latency)")
    ap.add_argument("--steps", type=int, default=40, help="LM train steps")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--lam", type=float, default=0.4)
    args = ap.parse_args()

    # 1. train a tiny LM briefly
    cfg = reduced(get_arch("gemma3-1b"))
    state, _ = train("gemma3-1b", steps=args.steps, batch=8, seq=64, lr=3e-3,
                     log_every=20)
    params = state.params

    # 2. harvest (hidden state -> next token) pairs into the DynamicIndex
    #    datastore; tag each pair with its source document (= sequence) so
    #    retrieval can be provenance-scoped per query
    batch = PIPE.batch_for_step(cfg, 999, 32, 64)
    hidden, _ = T.forward(params, cfg, batch, act_dtype=jnp.float32,
                          remat=False, return_hidden=True)
    keys_h = hidden[:, :-1].reshape(-1, cfg.d_model)
    vals = batch["tokens"][:, 1:].reshape(-1)
    n_docs, per_doc = 4, keys_h.shape[0] // 4
    sources = np.minimum(np.arange(keys_h.shape[0]) // per_doc, n_docs - 1)
    ds = knn_lm.DynamicDatastore.build(
        jax.random.PRNGKey(3), keys_h, vals, cfg.vocab,
        build_cfg=GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16),
        precision=args.precision, tier=args.tier,
        sources=sources.astype(np.int32), n_sources=n_docs,
        k=8, ef=32)
    engine = ds.attach_engine() if args.engine else None
    print(f"datastore: {len(ds)} entries, precision={args.precision} "
          f"tier={args.tier} engine={int(args.engine)}")

    # 3. retrieval-fused generation: the logit hook queries the index at
    #    every decode step, the token hook streams the new pairs back in
    stream = knn_lm.make_stream_hook(ds, insert_every=4)
    eng = ServeEngine(cfg, params, s_max=64, act_dtype=jnp.float32,
                      logit_hook=knn_lm.make_logit_hook(ds, lam=args.lam),
                      token_hook=stream)
    prompt = {"tokens": batch["tokens"][:4, :16]}
    n0 = len(ds)
    out = eng.generate(prompt, max_new_tokens=args.new_tokens)
    stream.flush()
    print(f"generated {out['tokens'].shape} fused tokens; datastore grew "
          f"{n0} -> {len(ds)} during decode")
    if engine is not None:
        s = engine.stats()
        print(f"engine: {s.n_completed} queries, {s.n_mutations} inserted, "
              f"retrieval p50={s.p50_ms:.1f}ms p99={s.p99_ms:.1f}ms "
              f"({s.n_buckets} jit buckets)")

    # 4. fused vs pure-LM NLL on the memorization corpus itself: queries
    #    AT stored keys retrieve their own next token, the classic win
    q = hidden[:8, :-1].reshape(-1, cfg.d_model)
    tgt = batch["tokens"][:8, 1:].reshape(-1)
    lm_logits = T.lm_logits(params, cfg, hidden[:8, :-1])
    lm_logits = lm_logits.reshape(-1, cfg.vocab)
    klp = ds.knn_log_probs(q)
    fused = knn_lm.fuse(lm_logits, klp, lam=args.lam)
    print(f"pure-LM NLL   : {nll(lm_logits, tgt):.4f}")
    print(f"kNN-fused NLL : {nll(fused, tgt):.4f}  (lam={args.lam})")

    # 5. provenance-scoped retrieval: restrict queries to one source doc
    klp_doc0 = ds.knn_log_probs(q[:64], filter=jnp.zeros((64,), jnp.int32))
    hit = jnp.isfinite(klp_doc0).any(-1).mean()
    print(f"doc-0-filtered retrieval: support on {float(hit):.0%} of "
          f"queries (labels 0..{n_docs - 1} indexed)")


if __name__ == "__main__":
    main()
