"""kNN-LM: GRNND as the retrieval substrate for a language model.

Trains a tiny LM, builds a GRNND datastore over its hidden states, and
shows retrieval-fused decoding improving next-token NLL on data that
repeats datastore content (the classic kNN-LM memorization win).

    PYTHONPATH=src python examples/knn_lm.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core.grnnd import GRNNDConfig
from repro.data import pipeline as PIPE
from repro.models import transformer as T
from repro.retrieval import knn_lm
from repro.launch.train import train


def main():
    # 1. train a tiny LM briefly
    cfg = reduced(get_arch("gemma3-1b"))
    state, _ = train("gemma3-1b", steps=40, batch=8, seq=64, lr=3e-3,
                     log_every=20)
    params = state.params

    # 2. harvest (hidden state -> next token) pairs into a datastore
    batch = PIPE.batch_for_step(cfg, 999, 32, 64)
    hidden, _ = T.forward(params, cfg, batch, act_dtype=jnp.float32,
                          remat=False, return_hidden=True)
    keys_h = hidden[:, :-1].reshape(-1, cfg.d_model)
    vals = batch["tokens"][:, 1:].reshape(-1)
    store = knn_lm.build_datastore(
        jax.random.PRNGKey(3), keys_h, vals,
        GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16))
    print(f"datastore: {store.keys.shape[0]} entries, "
          f"graph degree {float((store.graph >= 0).sum(1).mean()):.1f}")

    # 3. evaluate fused vs pure-LM NLL on a batch overlapping the datastore
    test = PIPE.batch_for_step(cfg, 999, 8, 64)  # same distribution/step
    hid, _ = T.forward(params, cfg, test, act_dtype=jnp.float32,
                       remat=False, return_hidden=True)
    q = hid[:, :-1].reshape(-1, cfg.d_model)
    tgt = test["tokens"][:, 1:].reshape(-1)

    lm_logits = T.lm_logits(params, cfg, hid[:, :-1]).reshape(
        -1, cfg.vocab)
    klp = knn_lm.knn_logits(store, q, cfg.vocab, k=8, ef=32)
    fused = knn_lm.fuse(lm_logits, klp, lam=0.4)

    def nll(lp):
        lsm = jax.nn.log_softmax(lp, -1)
        return float(-jnp.take_along_axis(
            lsm, tgt[:, None], axis=-1).mean())

    print(f"pure-LM NLL   : {nll(lm_logits):.4f}")
    print(f"kNN-fused NLL : {nll(fused):.4f}  (lam=0.4)")


if __name__ == "__main__":
    main()
