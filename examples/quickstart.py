"""Quickstart: build a GRNND graph, search it, measure recall.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import GRNNDConfig, build_graph, brute_force_knn, recall_at_k
from repro.core.search import search
from repro.data import synthetic


def main():
    key = jax.random.PRNGKey(0)

    # 1. a clustered vector dataset (SIFT-like, reduced scale)
    x = synthetic.make_preset(key, "sift-like", n=10_000)
    queries = synthetic.queries_from(jax.random.PRNGKey(1), x, 500)
    print(f"dataset: {x.shape[0]} vectors, d={x.shape[1]}")

    # 2. build the ANN graph with GRNND (disordered propagation, double-
    #    buffered fixed pools, reverse-edge sampling — paper Alg. 3)
    cfg = GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6, pairs_per_vertex=24)
    t0 = time.perf_counter()
    pool = build_graph(jax.random.PRNGKey(2), x, cfg)
    pool.ids.block_until_ready()
    print(f"built graph in {time.perf_counter()-t0:.2f}s "
          f"(mean degree {float(pool.degree().mean()):.1f})")

    # 3. search + evaluate against brute force
    gt = brute_force_knn(x, queries, k=10)
    t0 = time.perf_counter()
    res = search(x, pool.ids, queries, k=10, ef=48)
    res.ids.block_until_ready()
    dt = time.perf_counter() - t0
    rec = recall_at_k(res.ids, gt)
    print(f"recall@10 = {rec:.3f}   qps = {queries.shape[0]/dt:.0f}   "
          f"mean dist-evals/query = {float(res.n_expanded.mean()):.0f}")


if __name__ == "__main__":
    main()
