"""End-to-end driver (the paper's kind: index construction + serving).

Builds a GRNND index over a synthetic corpus and serves batched ANN queries
with a latency/recall report — the full pipeline the paper accelerates:
construction (its contribution) feeding online search.

    PYTHONPATH=src python examples/serve_ann.py [--n 30000] [--d 96]
"""
import argparse
import time

import jax

from repro.core import GRNNDConfig, build_graph, brute_force_knn, recall_at_k
from repro.core.search import search
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--d", type=int, default=96)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ef", type=int, default=48)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    x = synthetic.vector_dataset(key, args.n, args.d, n_clusters=128)

    # ---- offline stage: index construction (the paper's bottleneck) ----
    cfg = GRNNDConfig(s=16, r=32, t1=3, t2=4, rho=0.6, pairs_per_vertex=32)
    t0 = time.perf_counter()
    pool = build_graph(jax.random.PRNGKey(1), x, cfg)
    pool.ids.block_until_ready()
    build_s = time.perf_counter() - t0
    print(f"[build] n={args.n} d={args.d}  {build_s:.2f}s  "
          f"mean_degree={float(pool.degree().mean()):.1f}")

    # ---- online stage: batched query serving ----
    lat = []
    recs = []
    for b in range(args.batches):
        q = synthetic.queries_from(jax.random.fold_in(key, b), x,
                                   args.batch_size)
        t0 = time.perf_counter()
        res = search(x, pool.ids, q, k=10, ef=args.ef)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        if b == 0:
            continue  # first batch pays compile; measure steady state
        lat.append(dt)
        gt = brute_force_knn(x, q, 10)
        recs.append(recall_at_k(res.ids, gt))

    qps = args.batch_size / (sum(lat) / len(lat))
    print(f"[serve] batches={len(lat)} batch={args.batch_size} "
          f"ef={args.ef}")
    print(f"[serve] p50_latency={sorted(lat)[len(lat)//2]*1e3:.1f}ms  "
          f"qps={qps:.0f}  recall@10={sum(recs)/len(recs):.3f}")


if __name__ == "__main__":
    main()
