"""Train a reduced-config LM for a few hundred steps with checkpointing.

Any of the 10 assigned architectures works:

    PYTHONPATH=src python examples/train_tiny_lm.py --arch mamba2-130m
    PYTHONPATH=src python examples/train_tiny_lm.py --arch deepseek-moe-16b
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    state, hist = train(args.arch, steps=args.steps, batch=8, seq=128,
                        lr=3e-3, ckpt_dir=args.ckpt_dir, save_every=50,
                        log_every=20)
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f} at step {hist[0]['step']})")


if __name__ == "__main__":
    main()
