"""Checkpointing: atomic, step-tagged, mesh-agnostic save/restore.

Layout per step:
    <dir>/step_000123.tmp-<nonce>/   (write)
    <dir>/step_000123/               (atomic rename commit)
        manifest.json                (pytree structure + shapes + dtypes)
        arr_<i>.npy                  (one file per leaf, logical/global value)

Design points for 1000+-node restarts:
  * leaves are saved as *global* logical arrays, so a restart may use a
    different mesh/device count — `restore(..., shardings=...)` reshards on
    load (elastic scaling);
  * writes go to a temp dir and commit with an atomic rename: a crashed
    writer never corrupts the latest checkpoint;
  * `latest_step()` scans committed checkpoints only;
  * on real multi-host pods each host would write its addressable shards
    (orbax-style); on this single-process container jax.device_get already
    assembles the global view, and the resharding path is identical.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import uuid

import jax
import jax.numpy as jnp
import numpy as np


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return flat


def save(ckpt_dir: str | os.PathLike, step: int, tree) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f"step_{step:09d}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir()

    flat = _leaves_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i}.npy", arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))

    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore(ckpt_dir: str | os.PathLike, step: int, like_tree,
            shardings=None):
    """Restore into the structure of `like_tree`; optionally reshard.

    `like_tree` may be a pytree of arrays or ShapeDtypeStructs; `shardings`
    a matching pytree of NamedShardings for elastic / cross-mesh restore.
    """
    path = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    manifest = json.loads((path / "manifest.json").read_text())

    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(flat_like) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"target tree has {len(flat_like)}")

    arrays = []
    for i, (like, meta) in enumerate(zip(flat_like, manifest["leaves"])):
        arr = np.load(path / f"arr_{i}.npy")
        assert tuple(arr.shape) == tuple(like.shape), (
            meta["path"], arr.shape, like.shape)
        arrays.append(arr)

    if shardings is not None:
        flat_sh = jax.tree_util.tree_leaves(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    else:
        arrays = [jnp.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def prune_old(ckpt_dir: str | os.PathLike, keep: int = 3) -> None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = sorted(
        int(m.group(1)) for p in ckpt_dir.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", p.name)))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:09d}")
