"""Compatibility shims for jax API drift across the versions we support.

`jax.shard_map` (with the `check_vma` kwarg) replaced
`jax.experimental.shard_map.shard_map` (with `check_rep`) in newer jax;
this container pins an older jax, so call sites import `shard_map` from
here and always pass `check_vma=` — the shim renames the kwarg when
running on the experimental API.
"""
from __future__ import annotations

try:  # jax >= 0.6
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # jax <= 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    kwargs = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh across the (sizes, names) -> shape_tuple signature change."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a per-program list on older jax."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
