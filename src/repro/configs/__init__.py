"""Config registry: importing this package registers all architectures."""
from repro.configs.base import (
    ArchConfig, ShapeConfig, SHAPES, get_arch, list_archs, reduced)
from repro.configs.gemma2_2b import GEMMA2_2B
from repro.configs.h2o_danube_1_8b import H2O_DANUBE_1_8B
from repro.configs.gemma3_27b import GEMMA3_27B
from repro.configs.gemma3_1b import GEMMA3_1B
from repro.configs.deepseek_moe_16b import DEEPSEEK_MOE_16B
from repro.configs.qwen3_moe_235b_a22b import QWEN3_MOE_235B
from repro.configs.musicgen_large import MUSICGEN_LARGE
from repro.configs.mamba2_130m import MAMBA2_130M
from repro.configs.zamba2_7b import ZAMBA2_7B
from repro.configs.internvl2_2b import INTERNVL2_2B

ALL_ARCHS = [
    GEMMA2_2B, H2O_DANUBE_1_8B, GEMMA3_27B, GEMMA3_1B, DEEPSEEK_MOE_16B,
    QWEN3_MOE_235B, MUSICGEN_LARGE, MAMBA2_130M, ZAMBA2_7B, INTERNVL2_2B,
]

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "list_archs",
    "reduced", "ALL_ARCHS",
]
