"""Architecture + shape registry.

Every assigned architecture is an ArchConfig; every input-shape set is a
ShapeConfig.  Configs are frozen dataclasses so they can be static jit args.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

LayerKind = Literal["global", "local", "ssm", "shared_attn"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None        # defaults to d_model // n_heads
    # --- attention structure ---
    layer_pattern: tuple[str, ...] = ("global",)   # cycled to n_layers
    window: int = 0                  # sliding-window size for "local" layers
    attn_softcap: float = 0.0        # gemma2 attention logit soft-capping
    logit_softcap: float = 0.0       # gemma2 final logit soft-capping
    qk_norm: bool = False            # gemma3 / qwen3
    post_norm: bool = False          # gemma2/3 sandwich norms
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                # fine-grained expert hidden dim
    n_shared_experts: int = 0
    first_k_dense: int = 0           # deepseek: first k layers use dense FFN
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # --- modality ---
    modality: str = "text"           # text | audio_tokens | vision_text
    n_codebooks: int = 0             # musicgen
    vision_dim: int = 0              # internvl2 precomputed patch-embed dim
    vision_tokens: int = 0
    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    embed_scale: bool = False        # gemma: embeddings * sqrt(d_model)
    source: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> tuple[str, ...]:
        """The per-layer kind list, pattern cycled to n_layers."""
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: every layer is windowed, SSM, or the
        KV-bounded shared-attention block of a hybrid; pure full-attention
        stacks are not."""
        kinds = set(self.layer_kinds())
        if kinds <= {"ssm", "shared_attn", "local"}:
            return True
        # alternating local/global (gemma-style) and SWA: decode against a
        # seq-sharded KV is O(S) per token — eligible per DESIGN.md §5
        return "local" in kinds or "ssm" in kinds

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        hd = self.head_dim
        for kind in self.layer_kinds():
            if kind == "ssm":
                di, st, nh = self.d_inner, self.ssm_state, self.ssm_heads
                conv_ch = di + 2 * st
                total += d * (2 * di + 2 * st + nh)      # in_proj
                total += conv_ch * self.ssm_conv          # conv
                total += nh * 2                           # A, D
                total += di * d                           # out_proj
                total += 2 * d                            # norms
            elif kind == "shared_attn":
                continue  # counted once below
            else:
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d            # o_proj
                if self.n_experts and self._is_moe_layer_static():
                    total += d * self.n_experts           # router
                    total += self.n_experts * 3 * d * self.d_expert
                    total += self.n_shared_experts * 3 * d * self.d_expert
                else:
                    total += 3 * d * self.d_ff
                total += 2 * d
        if "shared_attn" in self.layer_kinds():
            hd = self.head_dim
            total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
            total += self.n_heads * hd * d
            total += 3 * d * self.d_ff + 2 * d
        if self.modality == "audio_tokens":
            total += (self.n_codebooks - 1) * v * d       # extra codebooks
            total += self.n_codebooks * v * d             # heads
        if self.modality == "vision_text":
            total += self.vision_dim * d + d * d          # projector
        return total

    def _is_moe_layer_static(self) -> bool:
        return self.n_experts > 0

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        n_moe_layers = max(self.n_layers - self.first_k_dense, 0)
        inactive = (self.n_experts - self.top_k) * 3 * d * self.d_expert
        return self.param_count() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    # import side-effect registration
    import repro.configs  # noqa: F401
    return _REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def truncate_units(cfg: ArchConfig, k: int) -> ArchConfig:
    """Same arch with only k repeats of the pattern unit (plus any
    first-k-dense prefix and non-divisible tail).  Used by the dry-run cost
    probes: cost(full) = cost(k=1) + (units-1) * [cost(k=2) - cost(k=1)],
    because XLA's cost_analysis counts scanned bodies once per while loop.
    """
    body = cfg.n_layers - cfg.first_k_dense
    unit = min(len(cfg.layer_pattern), body)
    tail = body - (body // unit) * unit
    n_layers = cfg.first_k_dense + unit * k + tail
    return dataclasses.replace(cfg, n_layers=n_layers,
                               name=f"{cfg.name}-u{k}")


def n_pattern_units(cfg: ArchConfig) -> int:
    body = cfg.n_layers - cfg.first_k_dense
    unit = min(len(cfg.layer_pattern), body)
    return body // unit


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=max(2, len(cfg.layer_pattern)),
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        window=min(cfg.window, 32) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_expert=32 if cfg.d_expert else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        first_k_dense=min(cfg.first_k_dense, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        vision_dim=32 if cfg.vision_dim else 0,
        vision_tokens=8 if cfg.vision_tokens else 0,
        name=cfg.name + "-smoke",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
