"""deepseek-moe-16b [moe] — fine-grained experts: 2 shared + 64 routed top-6.

First layer uses a dense FFN (d_ff = 10944); MoE layers use 1408-dim experts.
[arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base]
"""
from repro.configs.base import ArchConfig, register

DEEPSEEK_MOE_16B = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10_944,              # dense first layer
    vocab=102_400,
    layer_pattern=("global",),
    n_experts=64,
    top_k=6,
    d_expert=1408,            # the assignment's d_ff=1408 (expert hidden)
    n_shared_experts=2,
    first_k_dense=1,
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2401.06066; hf",
))
