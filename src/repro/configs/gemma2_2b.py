"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf:google/gemma-2-2b]
"""
from repro.configs.base import ArchConfig, register

GEMMA2_2B = register(ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab=256_000,
    layer_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norm=True,
    embed_scale=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
))
