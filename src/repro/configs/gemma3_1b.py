"""gemma3-1b [dense] — 5:1 local:global, single KV head, 128k context.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig, register

GEMMA3_1B = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=512,
    qk_norm=True,
    post_norm=True,
    embed_scale=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))
