"""gemma3-27b [dense] — 5:1 local:global attention, 128k context, qk-norm.

[hf:google/gemma-3-1b-pt family; unverified]
"""
from repro.configs.base import ArchConfig, register

GEMMA3_27B = register(ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21_504,
    vocab=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    qk_norm=True,
    post_norm=True,
    embed_scale=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-27b-pt; unverified",
))
