"""GRNND paper dataset configs — the paper's own benchmark shapes.

SIFT1M / DEEP1M / GIST1M (and reduced CPU-scale variants for this container).
These drive the paper-reproduction benchmarks and the GRNND distributed
dry-run config.
"""
from __future__ import annotations

import dataclasses

from repro.core.grnnd import GRNNDConfig


@dataclasses.dataclass(frozen=True)
class ANNDatasetConfig:
    name: str
    n: int
    d: int
    n_queries: int
    k: int = 10
    build: GRNNDConfig = GRNNDConfig()


# full-scale (TPU target; exercised via the dry-run)
SIFT1M = ANNDatasetConfig(
    "sift1m", n=1_000_000, d=128, n_queries=10_000,
    build=GRNNDConfig(s=24, r=48, t1=4, t2=6, rho=0.6, pairs_per_vertex=48,
                      chunk_size=4096))
DEEP1M = ANNDatasetConfig(
    "deep1m", n=1_000_000, d=96, n_queries=10_000,
    build=GRNNDConfig(s=24, r=48, t1=3, t2=6, rho=0.6, pairs_per_vertex=48,
                      chunk_size=4096))
GIST1M = ANNDatasetConfig(
    "gist1m", n=1_000_000, d=960, n_queries=1_000,
    build=GRNNDConfig(s=24, r=48, t1=5, t2=6, rho=0.6, pairs_per_vertex=48,
                      chunk_size=2048))

# reduced-scale (CPU container benchmarks; same structure)
SIFT_SMALL = ANNDatasetConfig(
    "sift-small", n=20_000, d=128, n_queries=500,
    build=GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6, pairs_per_vertex=24))
DEEP_SMALL = ANNDatasetConfig(
    "deep-small", n=20_000, d=96, n_queries=500,
    build=GRNNDConfig(s=12, r=24, t1=3, t2=4, rho=0.6, pairs_per_vertex=24))
GIST_SMALL = ANNDatasetConfig(
    "gist-small", n=8_000, d=960, n_queries=200,
    build=GRNNDConfig(s=12, r=24, t1=4, t2=4, rho=0.6, pairs_per_vertex=24))

# seconds-scale CPU build: the launch-CLI end-to-end smoke tier
# (tests/test_serving.py subprocess-runs build_index -> serve on it)
SIFT_DEMO = ANNDatasetConfig(
    "sift-demo", n=1_500, d=128, n_queries=100,
    build=GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16))

DATASETS = {c.name: c for c in
            [SIFT1M, DEEP1M, GIST1M, SIFT_SMALL, DEEP_SMALL, GIST_SMALL,
             SIFT_DEMO]}
