"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base]
"""
from repro.configs.base import ArchConfig, register

H2O_DANUBE_1_8B = register(ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=80,
    d_ff=6912,
    vocab=32_000,
    layer_pattern=("local",),       # SWA on every layer (mistral-style)
    window=4096,
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2401.16818; hf",
))
