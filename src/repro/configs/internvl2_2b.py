"""internvl2-2b [vlm] — InternViT frontend (stub) + InternLM2-1.8B backbone.

Backbone only per the assignment: `input_specs()` provides precomputed patch
embeddings (B, vision_tokens, vision_dim); the framework projects them into
the LM sequence (first `vision_tokens` positions).
[arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B]
"""
from repro.configs.base import ArchConfig, register

INTERNVL2_2B = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92_553,
    layer_pattern=("global",),
    modality="vision_text",
    vision_dim=1024,
    vision_tokens=256,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="arXiv:2404.16821; hf",
))
