"""mamba2-130m [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig, register

MAMBA2_130M = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
