"""musicgen-large [audio] — decoder-only over EnCodec tokens, 4 codebooks.

Backbone only per the assignment: the EnCodec frontend is a stub
(`input_specs()` provides the (B, S, n_codebooks) token grid directly).
[arXiv:2306.05284; hf:facebook/musicgen-large]
"""
from repro.configs.base import ArchConfig, register

MUSICGEN_LARGE = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=8192,
    vocab=2048,
    layer_pattern=("global",),
    modality="audio_tokens",
    n_codebooks=4,
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2306.05284; hf",
))
