"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, qk-norm, GQA kv=4.

[hf:Qwen/Qwen3-235B-A22B (config family per Qwen3-30B-A3B); hf]
"""
from repro.configs.base import ArchConfig, register

QWEN3_MOE_235B = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,                # kept for assignment fidelity (== d_expert)
    vocab=151_936,
    layer_pattern=("global",),
    n_experts=128,
    top_k=8,
    d_expert=1536,
    n_shared_experts=0,
    first_k_dense=0,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
