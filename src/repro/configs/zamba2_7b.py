"""zamba2-7b [hybrid] — Mamba2 backbone + periodically applied *shared*
attention block (one set of attention weights reused at every occurrence).

[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ArchConfig, register

ZAMBA2_7B = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14_336,
    vocab=32_000,
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "ssm", "shared_attn"),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2411.15242; unverified",
))
