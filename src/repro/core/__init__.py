"""GRNND core: the paper's contribution as a composable JAX library."""
from repro.core.grnnd import (
    GRNNDConfig, build_graph, build_graph_with_stats, update_round,
    reverse_edge_round)
from repro.core.pools import (
    Pool, Requests, empty_pool, init_random, insert_requests, merge_into)
from repro.core.search import SearchResult, search, medoid, default_visited_cap
from repro.core.recall import brute_force_knn, recall_at_k
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.distributed import (
    sharded_build_graph, make_sharded_builder, distributed_search,
    sharded_apply_requests)
from repro.core.vecstore import (
    PRECISIONS, VectorStore, encode, quantize_int8)
from repro.core.labels import (
    LabelStore, encode_labels, encode_label_sets, filtered_brute_force,
    filtered_recall_at_k)
from repro.core.layout import (
    OptimizedIndex, optimize, pack_adjacency, unpack_adjacency,
    packed_degree, order_permutation, prune_adjacency)

__all__ = [
    "GRNNDConfig", "build_graph", "build_graph_with_stats", "update_round",
    "reverse_edge_round", "Pool", "Requests", "empty_pool", "init_random",
    "insert_requests", "merge_into", "SearchResult", "search", "medoid",
    "default_visited_cap", "brute_force_knn", "recall_at_k",
    "DynamicConfig", "DynamicIndex",
    "sharded_build_graph", "make_sharded_builder", "distributed_search",
    "sharded_apply_requests",
    "PRECISIONS", "VectorStore", "encode", "quantize_int8",
    "LabelStore", "encode_labels", "encode_label_sets",
    "filtered_brute_force", "filtered_recall_at_k",
    "OptimizedIndex", "optimize", "pack_adjacency", "unpack_adjacency",
    "packed_degree", "order_permutation", "prune_adjacency",
]
