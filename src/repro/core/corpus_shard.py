"""Corpus-sharded index: break the single-device memory ceiling.

Every other serving path replicates the full corpus per device —
`distributed_search` shards only *queries*, so N is capped by one device's
memory (ROADMAP ceiling 1).  This module shards the CORPUS: shard `s` of S
owns the contiguous row range [s·n_loc, (s+1)·n_loc) of the vectors, the
graph rows, the validity mask, the label words, the rescore tier, and the
layout `ids_map` — every O(N) operand — while per-query state (beam,
visited set, result heap) stays O(Q) and replicates.

The partition/id-map contract (DESIGN.md §11):

  * `n_loc = ceil(N / S)`; global id g lives on shard `g // n_loc` at local
    row `g % n_loc` (`shard_of` / `local_of` / `global_of`; the round-trip
    is the identity — tests/test_corpus_shard.py property tier).  The last
    shard may own fewer than n_loc real rows; its tail pads are
    unreachable (no graph edge, entry, or id map ever points >= N).
  * Graph rows are sharded by OWNER row but keep GLOBAL neighbor ids
    inside, so an edge crossing a shard boundary needs no rewriting.
  * Composition with the PR 6 layout pass: `shard_optimized` slices an
    `OptimizedIndex` along its PERMUTED rows — internal traversal ids are
    the permuted numbering, and each shard owns its slice of `inv`
    (`ids_map`), applied owner-side in the final gather.  global→(shard,
    local) therefore composes as `g_orig → perm[g_orig] → (shard, local)`.

The search (GGNN-style shard-local kernels, exact global semantics): every
step of the replicated beam search factors over corpus rows — the fused
`search_expand` kernel scores each neighbor against only that neighbor's
own vector row.  So each shard runs the kernel SHARD-LOCALLY on its slice
(neighbors it does not own masked to the -1 sentinel, exactly an empty
graph slot) and the per-slot outputs are reduced across shards with
order-free owner-combines: min for distances (+inf from non-owners), max
for ids (-1 from non-owners) and flags.  Exactly one shard contributes a
finite/valid value per slot, so the combine involves no fp re-association
— the reduced step is BITWISE the replicated step, for any shard count
(the invariance tier, tests/test_corpus_shard.py).  The final cross-shard
top-k reduction — owner-rescored candidates carrying re-based GLOBAL ids —
goes through the same order-free `ops.topr_merge` the build uses.

Entry points are owner-local in the same sense: the entry vertex lives on
one shard; its (tiny) dequantized row and validity bit are captured at
`shard()` time so the replicated beam seeds without a cross-shard gather.

Build side (`sharded_build`, the Wang et al. divide-and-conquer recipe):
per-partition GRNND builds — peak memory O(n_loc·D) per build — produce a
block-diagonal pool; cross-boundary candidates with true traversal-space
distances are then injected through the standard request staging and
stitched by `DynamicIndex`'s localized-frontier propagation rounds
(`core.dynamic._localized_round` over the full frontier), plus one
reverse-edge pass, until RNG descent has repaired the boundaries
(quality tier: tests/test_corpus_shard.py vs the test_recall.py floor).

Execution: `sharded_search(index, queries)` runs the S per-shard kernel
calls in one process (the replicated reference, also the 1-device serving
fallback); `core.distributed.corpus_sharded_search` runs the identical
body as a shard_map over a device mesh — one shard per device, collectives
for the owner-combines — and is bitwise-identical to the reference.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import labels as L
from repro.core import pools as P
from repro.core import vecstore as VS
from repro.core.grnnd import GRNNDConfig, build_graph, reverse_edge_round
from repro.core.search import (
    SearchResult, _rescore_merge, _table_insert, _table_member,
    default_visited_cap, medoid)
from repro.kernels import ops

__all__ = [
    "CorpusShardedIndex", "shard", "shard_optimized", "sharded_search",
    "sharded_build", "shard_bounds", "shard_of", "local_of", "global_of",
    "memory_report",
]


# ---------------------------------------------------------------------------
# partition layout / id maps
# ---------------------------------------------------------------------------

def shard_bounds(n: int, n_shards: int) -> tuple[tuple[int, ...], int]:
    """(row0 per shard, n_loc) for the contiguous equal partition of [0, n).

    `n_loc = ceil(n / n_shards)`; shard s owns global rows
    [row0_s, min(row0_s + n_loc, n)) — the last shard may own fewer, and
    its slice is padded to n_loc with unreachable rows.
    """
    assert n_shards >= 1 and n >= 1, (n, n_shards)
    n_loc = -(-n // n_shards)
    return tuple(s * n_loc for s in range(n_shards)), n_loc


def shard_of(g, n_loc: int):
    """Owning shard of global id(s) g."""
    return g // n_loc


def local_of(g, n_loc: int):
    """Local row of global id(s) g on its owning shard."""
    return g % n_loc


def global_of(s, loc, n_loc: int):
    """Global id of local row `loc` on shard `s` (inverse of the above)."""
    return s * n_loc + loc


# ---------------------------------------------------------------------------
# the sharded index
# ---------------------------------------------------------------------------

class CorpusShardedIndex(NamedTuple):
    """Per-shard stacked operands: every array's leading axis is the shard
    axis (S, n_loc, ...), ready to `device_put` with a sharded leading-dim
    PartitionSpec (one shard per device) or to loop over in process.

    `data` holds the traversal tier's stored bytes (fp32/bf16/int8 per the
    precision ladder); `scale`/`offset` are the frozen per-dim quantizer
    params, replicated (they are (D,), not O(N)).  `graphs` rows carry
    GLOBAL neighbor ids.  `rescores` is the fp32 exact tier, pre-
    dequantized so the owner-side re-rank is row-for-row the replicated
    rescore math; under `shard(tier="host")` it is instead a
    `vecstore.HostTier` over the UNSTACKED (N, D) tier — contiguous
    partitions make the flattened stacked index equal the global id, so
    the host gather indexes global ids directly and no per-shard device
    slice exists at all (DESIGN.md §13).  `entry_row`/`entry_valid`/
    `entry_words` capture the entry vertex's owner-side state at shard()
    time (see module docstring).
    """
    data: jnp.ndarray                    # (S, n_loc, D) stored bytes
    scale: jnp.ndarray | None            # (D,) frozen quantizer (int8)
    offset: jnp.ndarray | None           # (D,)
    graphs: jnp.ndarray                  # (S, n_loc, R) int32, GLOBAL ids
    row0s: jnp.ndarray                   # (S,) int32 first global row
    valids: jnp.ndarray | None           # (S, n_loc) bool
    rescores: object | None              # (S, n_loc, D) fp32 exact tier,
                                         #   or a host-pinned VS.HostTier
    vwords: jnp.ndarray | None           # (S, n_loc, W) packed label words
    ids_maps: jnp.ndarray | None         # (S, n_loc) int32 layout inv slice
    entry: jnp.ndarray                   # () int32 global entry id
    entry_row: jnp.ndarray               # (D,) fp32 dequantized entry row
    entry_valid: jnp.ndarray | None      # () bool — valid[entry]
    entry_words: jnp.ndarray | None      # (W,) — vwords[entry]
    n: int                               # true corpus size

    @property
    def n_shards(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_loc(self) -> int:
        return int(self.data.shape[1])

    def search(self, queries, **kw) -> SearchResult:
        return sharded_search(self, queries, **kw)


def _stack_shards(a, row0s: Sequence[int], n_loc: int, fill):
    """Slice rows into (S, n_loc, ...) with `fill`-padded tails."""
    import numpy as np
    a = np.asarray(a)
    n = a.shape[0]
    out = np.full((len(row0s), n_loc) + a.shape[1:], fill, a.dtype)
    for s, row0 in enumerate(row0s):
        m = min(n_loc, n - row0)
        out[s, :m] = a[row0:row0 + m]
    return jnp.asarray(out)


def shard(
    x,
    graph,
    n_shards: int,
    *,
    valid=None,
    rescore=None,
    labels=None,
    ids_map=None,
    entry=None,
    tier: str = "device",
) -> CorpusShardedIndex:
    """Partition a built index into a `CorpusShardedIndex`.

    `x` is the traversal tier (fp32 array or VectorStore), `graph` a
    `pools.Pool` or raw (N, R) id array; `valid`/`rescore`/`labels`/
    `ids_map` are the same optional operands `core.search.search` takes,
    each sliced to its owner shard.  `entry` defaults to the medoid of the
    FULL corpus (computed here, while it is still in one piece — the
    sharded index stores only the entry's id, row, and flags).

    `tier` places the fp32 rescore tier (DESIGN.md §13): "device" slices
    it per shard like every other O(N) operand; "host" pins the whole
    dequantized tier on the CPU backend (`vecstore.HostTier`) — devices
    then hold int8 + graph only, and the re-rank gathers the final ef
    rows per query across the boundary, bitwise-equal either way.
    """
    assert tier in VS.PLACEMENTS, tier
    gids = graph.ids if hasattr(graph, "ids") else graph
    n = int(VS.parts(x)[0].shape[0])
    assert gids.shape[0] == n, (gids.shape, n)
    row0s, n_loc = shard_bounds(n, n_shards)

    if entry is None:
        entry = medoid(x, None if valid is None else jnp.asarray(valid))
    entry = jnp.asarray(entry, jnp.int32)
    entry_row = VS.take(x, entry)

    xd, xs, xo = VS.parts(x)
    vwords = None if labels is None else L.store_words(labels)
    # the dequantized exact tier: owner-side rescue math must be row-for-row
    # the replicated `VS.take(rescore, ·)` gather (bitwise contract)
    resc = None if rescore is None else VS.dequant(rescore)
    if resc is not None and tier == "host":
        # host placement keeps the tier UNSTACKED — the HostTier gathers
        # by global id, and global id == flattened stacked index anyway
        # (contiguous partitions; only the last shard pads)
        resc_field = VS.HostTier(resc)
    elif resc is not None:
        resc_field = _stack_shards(resc, row0s, n_loc, 0)
    else:
        resc_field = None
    idx = CorpusShardedIndex(
        data=_stack_shards(xd, row0s, n_loc, 0),
        scale=xs, offset=xo,
        graphs=_stack_shards(gids, row0s, n_loc, -1),
        row0s=jnp.asarray(row0s, jnp.int32),
        valids=(None if valid is None
                else _stack_shards(jnp.asarray(valid), row0s, n_loc, False)),
        rescores=resc_field,
        vwords=(None if vwords is None
                else _stack_shards(vwords, row0s, n_loc, 0)),
        ids_maps=(None if ids_map is None
                  else _stack_shards(jnp.asarray(ids_map), row0s, n_loc, -1)),
        entry=entry, entry_row=entry_row,
        entry_valid=(None if valid is None else jnp.asarray(valid)[entry]),
        entry_words=(None if vwords is None else vwords[entry]),
        n=n,
    )
    return idx


def shard_optimized(opt, n_shards: int,
                    tier: str = "device") -> CorpusShardedIndex:
    """Partition a PR 6 `layout.OptimizedIndex` (the composition contract):
    shards slice the PERMUTED rows; each shard owns its slice of `inv`, so
    returned ids come back in the caller's original numbering."""
    return shard(opt.x, opt.graph_ids, n_shards, valid=opt.valid,
                 rescore=opt.rescore, labels=opt.vwords,
                 ids_map=opt.inv, entry=opt.entry, tier=tier)


# ---------------------------------------------------------------------------
# owner-combines
# ---------------------------------------------------------------------------

def _cmin(parts, axes):
    """Min over local shard contributions, then over mesh axes.  Non-owners
    contribute +inf, so exactly one finite value survives per slot — no fp
    re-association, hence order-free and exact."""
    a = functools.reduce(jnp.minimum, parts)
    return a if axes is None else jax.lax.pmin(a, axes)


def _cmax_i32(parts, axes):
    """Max over int32 contributions (non-owners contribute the -1
    sentinel); same exactness argument as `_cmin`."""
    a = functools.reduce(jnp.maximum, parts)
    return a if axes is None else jax.lax.pmax(a, axes)


def _cor(parts, axes):
    """Logical OR across shards (non-owners contribute False)."""
    a = functools.reduce(jnp.logical_or, parts)
    if axes is None:
        return a
    return jax.lax.pmax(a.astype(jnp.int32), axes).astype(bool)


def _owner(ids, row0, n_own, n_loc):
    """(owned mask, clipped local rows) of global `ids` for one shard."""
    loc = ids - row0
    owned = (ids >= 0) & (loc >= 0) & (loc < n_own)
    return owned, jnp.clip(loc, 0, n_loc - 1)


# ---------------------------------------------------------------------------
# the corpus-sharded search body
# ---------------------------------------------------------------------------

def _corpus_body(
    data, scale, offset, graphs, row0s, queries, entry, entry_row,
    entry_valid, rescores, valids, ids_maps, vwords, entry_words, fwords,
    *,
    n: int,
    k: int,
    ef: int,
    max_steps: int,
    visited: str,
    visited_cap: int,
    axes: tuple | None,
) -> SearchResult:
    """The beam-search loop of `search._search_impl`, with every gather of
    O(N) state replaced by shard-local work + an owner-combine.

    Operands arrive with a leading LOCAL shard axis: the in-process
    reference passes the full (S, n_loc, ...) stacks with `axes=None`;
    the shard_map executor (core/distributed.py) passes each device its
    (1, n_loc, ...) slice plus the mesh axis names, and the `_c*` combines
    finish the reduction with collectives.  Both routes reduce the same S
    single-owner contributions with order-free min/max, so they are
    bitwise-identical to each other AND to the replicated search
    (tests/test_corpus_shard.py).
    """
    s_l, n_loc, _r = graphs.shape
    q = queries.shape[0]
    qrows = jnp.arange(q, dtype=jnp.int32)
    filtered = fwords is not None
    queries = queries.astype(jnp.float32)
    n_owns = [jnp.minimum(n_loc, n - row0s[s]) for s in range(s_l)]

    d_entry = ops.rowwise_sqdist(
        queries, jnp.broadcast_to(entry_row, queries.shape))
    if entry_valid is not None:
        d_entry = jnp.where(entry_valid, d_entry, jnp.inf)
    cand_ids = jnp.full((q, ef), -1, jnp.int32).at[:, 0].set(entry)
    cand_dists = jnp.full((q, ef), jnp.inf, jnp.float32).at[:, 0].set(d_entry)
    expanded = jnp.zeros((q, ef), bool)
    n_exp = jnp.zeros((q,), jnp.int32)

    if filtered:
        e_ok = jnp.any((entry_words[None, :] & fwords) != 0, axis=-1)
        e_ok = e_ok & jnp.isfinite(d_entry)
        res_ids = jnp.full((q, ef), -1, jnp.int32).at[:, 0].set(
            jnp.where(e_ok, entry, -1))
        res_dists = jnp.full((q, ef), jnp.inf, jnp.float32).at[:, 0].set(
            jnp.where(e_ok, d_entry, jnp.inf))

    entry_col = jnp.broadcast_to(entry, (q, 1)).astype(jnp.int32)
    if visited == "dense":
        vstate = jnp.zeros((q, n), bool).at[:, entry].set(True)
    else:
        vstate = _table_insert(jnp.full((q, visited_cap), -1, jnp.int32),
                               entry_col)
    # the kernel always probes an empty dummy table here: freshness against
    # the REAL visited set is refined below on GLOBAL ids (the local kernel
    # only sees local ids, which must not touch the id-keyed table)
    dummy = jnp.full((q, 1), -1, jnp.int32)

    def cond(state):
        frontier = (state[0] >= 0) & ~state[2]
        return (state[5] < max_steps) & jnp.any(frontier)

    def body(state):
        cand_ids, cand_dists, expanded, vstate, n_exp, steps = state[:6]
        frontier_d = jnp.where((cand_ids >= 0) & ~expanded, cand_dists,
                               jnp.inf)
        sel = jnp.argmin(frontier_d, axis=-1)                      # (Q,)
        active = jnp.isfinite(jnp.min(frontier_d, axis=-1))        # (Q,)
        sel_id = cand_ids[qrows, sel]
        expanded = expanded.at[qrows, sel].set(True)

        # owner-side fetch of the selected vertices' graph rows (neighbor
        # ids inside the rows are already global)
        parts = []
        for s in range(s_l):
            owned, loc = _owner(sel_id, row0s[s], n_owns[s], n_loc)
            parts.append(jnp.where(owned[:, None], graphs[s][loc], -1))
        nbrs = _cmax_i32(parts, axes)                              # (Q, R)
        nbrs = jnp.where(active[:, None] & (nbrs >= 0), nbrs, -1)

        # shard-local fused expansion: each shard scores the neighbors it
        # owns (others masked to the empty sentinel) on its own x slice
        dq_parts, ok_parts, al_parts = [], [], []
        for s in range(s_l):
            owned, loc = _owner(nbrs, row0s[s], n_owns[s], n_loc)
            nloc = jnp.where(owned, loc, -1)
            x_s = (data[s] if scale is None
                   else VS.VectorStore(data[s], scale, offset))
            out = ops.search_expand(
                x_s, queries, nloc, dummy,
                None if valids is None else valids[s],
                vwords[s] if filtered else None,
                fwords if filtered else None)
            # dummy table => the kernel's fresh IS its live/valid mask
            dq_parts.append(out[1])
            ok_parts.append(out[2])
            if filtered:
                al_parts.append(out[3])
        dq = _cmin(dq_parts, axes)
        ok = _cor(ok_parts, axes)
        nbrs = jnp.where(ok, nbrs, -1)
        if filtered:
            allowed = _cor(al_parts, axes)

        # visited-set logic runs replicated on GLOBAL ids — the same math
        # the replicated search applies (dense: exact bitmask; hashed: the
        # kernel's probe formula via search._table_member)
        if visited == "dense":
            seen = vstate[qrows[:, None], jnp.clip(nbrs, 0)]
            fresh = ok & ~seen
            vstate = vstate.at[qrows[:, None], jnp.clip(nbrs, 0)].max(fresh)
        else:
            fresh = ok & ~_table_member(vstate, nbrs)
            vstate = _table_insert(vstate, jnp.where(fresh, nbrs, -1))

        dq = jnp.where(fresh, dq, jnp.inf)
        n_exp = n_exp + jnp.sum(fresh, axis=-1, dtype=jnp.int32)

        all_ids = jnp.concatenate([cand_ids, jnp.where(fresh, nbrs, -1)],
                                  axis=-1)
        all_d = jnp.concatenate([cand_dists, dq], axis=-1)
        new_ids, new_d = ops.topr_merge(all_ids, all_d, ef)

        exp_src = jnp.where(expanded & (cand_ids >= 0), cand_ids, -2)
        new_expanded = jnp.any(
            new_ids[:, :, None] == exp_src[:, None, :], axis=-1)
        new_expanded = new_expanded | (new_ids < 0)

        next_state = (new_ids, new_d, new_expanded, vstate, n_exp, steps + 1)
        if filtered:
            keep = fresh & allowed
            res_ids, res_dists = ops.topr_merge(
                jnp.concatenate([state[6], jnp.where(keep, nbrs, -1)],
                                axis=-1),
                jnp.concatenate([state[7], jnp.where(keep, dq, jnp.inf)],
                                axis=-1),
                ef)
            next_state = next_state + (res_ids, res_dists)
        return next_state

    state = (cand_ids, cand_dists, expanded, vstate, n_exp, jnp.int32(0))
    if filtered:
        state = state + (res_ids, res_dists)
    state = jax.lax.while_loop(cond, body, state)
    cand_ids, cand_dists, n_exp = state[0], state[1], state[4]
    out_ids, out_dists = ((state[6], state[7]) if filtered
                          else (cand_ids, cand_dists))

    if rescores is not None:
        # the cross-shard top-k reduction: each shard re-ranks the final ef
        # candidates IT OWNS against its fp32 tier slice (+inf elsewhere,
        # ids already re-based to global), and the order-free `topr_merge`
        # finishes the reduce — the same primitive, and bitwise the
        # replicated rescore (single-owner distances, no re-association)
        d_parts = []
        for s in range(s_l):
            owned, loc = _owner(out_ids, row0s[s], n_owns[s], n_loc)
            rv = rescores[s][loc]                          # (Q, ef, D)
            diff = queries[:, None, :] - rv
            d_parts.append(jnp.where(owned, jnp.sum(diff * diff, axis=-1),
                                     jnp.inf))
        d_exact = _cmin(d_parts, axes)
        out_ids, out_dists = ops.topr_merge(out_ids, d_exact, ef)

    out_ids, out_dists = out_ids[:, :k], out_dists[:, :k]
    if ids_maps is not None:
        # owner-side slice of the layout pass's inverse permutation
        parts = []
        for s in range(s_l):
            owned, loc = _owner(out_ids, row0s[s], n_owns[s], n_loc)
            parts.append(jnp.where(owned, ids_maps[s][loc], -1))
        out_ids = jnp.where(out_ids >= 0, _cmax_i32(parts, axes), -1)
    return SearchResult(out_ids, out_dists, n_exp)


@functools.partial(
    jax.jit,
    static_argnames=("n", "k", "ef", "max_steps", "visited", "visited_cap",
                     "backend"))
def _reference_impl(data, scale, offset, graphs, row0s, queries, entry,
                    entry_row, entry_valid, rescores, valids, ids_maps,
                    vwords, entry_words, fwords, *, n, k, ef, max_steps,
                    visited, visited_cap, backend):
    """In-process execution: the full shard stacks, combines as plain
    jnp.min/max folds.  `backend` is part of the jit key only (kernels
    dispatch at trace time, the `search._search_impl` contract)."""
    del backend
    return _corpus_body(data, scale, offset, graphs, row0s, queries, entry,
                        entry_row, entry_valid, rescores, valids, ids_maps,
                        vwords, entry_words, fwords, n=n, k=k, ef=ef,
                        max_steps=max_steps, visited=visited,
                        visited_cap=visited_cap, axes=None)


def sharded_search(
    index: CorpusShardedIndex,
    queries: jnp.ndarray,
    *,
    k: int = 10,
    ef: int = 64,
    max_steps: int = 512,
    visited: str = "dense",
    visited_cap: int | None = None,
    filter=None,
    overfetch: int = 4,
    mesh=None,
    axes: Sequence[str] = ("data",),
) -> SearchResult:
    """Corpus-sharded beam search; bitwise-identical to the replicated
    `core.search.search` over the unsharded operands, for ANY shard count.

    Without `mesh` the S per-shard kernel calls run in one process (the
    replicated reference — every shard's slice is resident, so this mode
    proves semantics but not the memory ceiling).  With a `mesh` of
    exactly `index.n_shards` devices the identical body runs as a
    shard_map (one shard per device, owner-combines as collectives) via
    `core.distributed.corpus_sharded_search` — per-device memory then
    holds 1/S of every O(N) operand.

    `filter` is the per-query predicate in any `core.labels.query_words`
    form; the index must have been sharded with `labels=`.
    """
    assert ef >= k
    assert visited in ("dense", "hashed"), visited
    if filter is not None:
        assert index.vwords is not None, \
            "filtered search needs an index sharded with labels="
        fwords = L.query_words(filter, index.vwords.shape[-1])
        ef = max(ef, overfetch * k)
    else:
        fwords = None
    if visited == "dense":
        cap = 0
    else:
        cap = (visited_cap if visited_cap is not None
               else default_visited_cap(ef))
    host = VS.is_host(index.rescores)
    if host:
        # host-cold tier (DESIGN.md §13): traversal runs without the
        # rescore/ids_map operands and keeps the full ef beam (k=ef); the
        # returned GLOBAL ids drive the host gather, then the same
        # `_rescore_merge` program as the replicated host path re-ranks.
        # The deferred ids_map is the flattened stack — flat index ==
        # global id under contiguous partitions, so the single gather is
        # value-for-value the owner-side `_cmax_i32` fold.
        run_idx = index._replace(rescores=None, ids_maps=None)
        k_run = ef
    else:
        run_idx, k_run = index, k
    if mesh is not None:
        from repro.core import distributed as D
        res = D.corpus_sharded_search(
            mesh, axes, run_idx, queries, k=k_run, ef=ef,
            max_steps=max_steps, visited=visited, visited_cap=cap,
            fwords=fwords)
    else:
        res = _reference_impl(
            run_idx.data, run_idx.scale, run_idx.offset, run_idx.graphs,
            run_idx.row0s, queries, run_idx.entry, run_idx.entry_row,
            run_idx.entry_valid, run_idx.rescores, run_idx.valids,
            run_idx.ids_maps, run_idx.vwords, run_idx.entry_words, fwords,
            n=run_idx.n, k=k_run, ef=ef, max_steps=max_steps,
            visited=visited, visited_cap=cap,
            backend=ops.effective_backend())
    if not host:
        return res
    rv = index.rescores.gather(res.ids)                    # (Q, ef, D)
    flat_map = (None if index.ids_maps is None
                else index.ids_maps.reshape(-1))
    out_ids, out_dists = _rescore_merge(
        res.ids, rv, jnp.asarray(queries, jnp.float32), flat_map, k=k)
    return SearchResult(out_ids, out_dists, res.n_expanded)


# ---------------------------------------------------------------------------
# sharded build: per-partition GRNND + cross-boundary merge-refine
# ---------------------------------------------------------------------------

def _cross_candidates(key, n: int, row0s, n_loc: int, c: int) -> jnp.ndarray:
    """(N, c) uniform global ids from OTHER shards for every vertex: draw
    r in [0, n - n_own(v)) and wrap around the owner's range."""
    rows = jnp.arange(n, dtype=jnp.int32)
    s = rows // n_loc
    row0 = s * n_loc
    n_own = jnp.minimum(n_loc, n - row0)
    span = jnp.maximum(n - n_own, 1)
    r = jax.random.randint(key, (n, c), 0, 2**31 - 1, jnp.int32)
    return ((row0 + n_own)[:, None] + r % span[:, None]) % n


def sharded_build(
    key: jax.Array,
    x,
    cfg: GRNNDConfig,
    n_shards: int,
    *,
    merge_rounds: int = 3,
    cross_candidates: int = 8,
) -> P.Pool:
    """Divide-and-conquer build (Wang et al., PAPERS.md): per-partition
    GRNND subgraphs, then cross-boundary merge-refine rounds.

    Each partition builds independently on its own slice (peak build
    memory O(n_loc·D·s) instead of O(N·D·s)); local pool ids are re-based
    to global and concatenated into a block-diagonal pool.  Each of the
    `merge_rounds` rounds then (1) injects `cross_candidates` random
    OTHER-shard candidates per vertex — true traversal-space distances via
    the fused gather kernel, staged through the standard order-free
    request pipeline — and (2) runs one localized-frontier propagation
    round (`core.dynamic._localized_round`, the DynamicIndex primitive)
    over the full frontier, so RNG descent redirects the injected edges
    into the boundary-crossing neighborhoods the independent builds could
    not see.  A reverse-edge pass between rounds symmetrizes them.

    Returns a standard global (N, R) `pools.Pool` — searchable replicated,
    or sharded again via `shard()` (quality tier:
    tests/test_corpus_shard.py vs the test_recall.py recall floor).
    """
    from repro.core.dynamic import _localized_round
    assert n_shards >= 1
    if n_shards == 1:
        return build_graph(key, x, cfg)
    xd, xs, xo = VS.parts(x)
    n = int(xd.shape[0])
    row0s, n_loc = shard_bounds(n, n_shards)
    assert n_loc > cfg.s, \
        f"shard size {n_loc} too small for s={cfg.s} init sampling"

    ids_parts, d_parts = [], []
    for s, row0 in enumerate(row0s):
        m = min(n_loc, n - row0)
        x_s = (VS.VectorStore(xd[row0:row0 + m], xs, xo) if xs is not None
               else xd[row0:row0 + m])
        p = build_graph(jax.random.fold_in(key, s), x_s, cfg)
        ids_parts.append(jnp.where(p.ids >= 0, p.ids + row0, -1))
        d_parts.append(p.dists)
    pool = P.Pool(jnp.concatenate(ids_parts), jnp.concatenate(d_parts))

    frontier = jnp.arange(n, dtype=jnp.int32)
    owners = jnp.repeat(frontier, cross_candidates)
    backend = ops.effective_backend()
    for t in range(merge_rounds):
        kt = jax.random.fold_in(jax.random.fold_in(key, 7919), t)
        cand = _cross_candidates(jax.random.fold_in(kt, 0), n, row0s,
                                 n_loc, cross_candidates).reshape(-1)
        d = ops.gather_sqdist(x, owners, cand)
        req = P.Requests(
            dst=jnp.concatenate([owners, cand]),
            src=jnp.concatenate([cand, owners]),
            dist=jnp.concatenate([d, d]),
        )
        pool = P.insert_requests(pool, req, cap=cfg.cap)
        pool = _localized_round(
            x, pool.ids, pool.dists, frontier, jax.random.fold_in(kt, 1),
            pairs=cfg.pairs_per_vertex, cap=cfg.cap, backend=backend)
        if t != merge_rounds - 1:
            pool = reverse_edge_round(pool, cfg)
    return pool


# ---------------------------------------------------------------------------
# memory accounting (the N-ceiling story, benchmarks/fig13)
# ---------------------------------------------------------------------------

def memory_report(index: CorpusShardedIndex) -> dict:
    """Bytes of O(N) index state per shard vs replicated-per-device.

    `per_shard` is what ONE device holds under corpus sharding (its slice
    of every O(N) operand plus the tiny replicated entry state);
    `replicated` is what the query-sharded layout puts on EVERY device
    (the same operands at full length).  Per-query search state (beam,
    visited table) is O(Q) in both layouts and excluded.
    """
    def nbytes(a):
        return 0 if a is None else int(a.size) * a.dtype.itemsize

    # a host-pinned rescore tier contributes ZERO device bytes (the §13
    # contract the fig15 smoke gates on); its footprint is reported
    # separately as host bytes
    host = VS.is_host(index.rescores)
    resc_dev = None if host else index.rescores
    sliced = (index.data, index.graphs, index.valids, resc_dev,
              index.vwords, index.ids_maps)
    per_slice = sum(nbytes(a) // index.n_shards for a in sliced)
    rep_small = (nbytes(index.scale) + nbytes(index.offset)
                 + nbytes(index.entry_row))
    # replicated layout: the true-N rows of every operand on every device
    frac = index.n / float(index.n_shards * index.n_loc)
    replicated = int(sum(nbytes(a) for a in sliced) * frac) + rep_small
    return {
        "n": index.n,
        "n_shards": index.n_shards,
        "n_loc": index.n_loc,
        "per_shard_bytes": per_slice + rep_small,
        "replicated_bytes": replicated,
        "rescore_device_bytes": nbytes(resc_dev) // index.n_shards,
        "rescore_host_bytes": index.rescores.host_bytes() if host else 0,
    }
