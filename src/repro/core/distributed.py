"""Distributed GRNND build: vertex-sharded shard_map over the device mesh.

The paper lists multi-GPU/distributed deployment as future work (§6); this
module implements it for TPU pods.  Layout:

  * vectors `x` are replicated (vector payloads are the gather-heavy side;
    at N·D ≤ a few GiB replication is the right trade — a dim-sharded
    variant with partial-distance all-reduce is sketched in DESIGN.md §4);
  * pools are sharded over vertices along the (possibly multi-axis) data
    dimension of the mesh;
  * each shard generates redirect requests from its local vertices; requests
    whose destination lives on another shard are exchanged — the exact
    variant all-gathers the (dst, src, dist) triples (tiny vs vector data),
    the optimized variant buckets them per destination shard and uses
    all_to_all (see EXPERIMENTS.md §Perf);
  * survivors never leave their shard (a vertex's own write buffer is local),
    so only the redirect triples travel.

Determinism: identical results for any shard count, because the merge stage
is the same order-free topr_merge dataflow as the single-device build.

Serving side — TWO sharding layouts, two ceilings (DESIGN.md §11.4):

  * `distributed_search` shards *queries* over the mesh (x and the graph
    replicated; per-query search state — beam + visited set — stays
    shard-local, no collectives inside the loop).  With `visited="hashed"`
    the per-shard state is O(q_loc · visited_cap), independent of N — the
    layout for "millions of users" traffic (DESIGN.md §6.4).  Throughput
    scales with devices; N stays capped by ONE device's memory.
  * `corpus_sharded_search` shards the *corpus* (core/corpus_shard.py):
    each device owns 1/S of the vectors, graph rows, labels, valid mask,
    rescore tier, and layout map, runs the fused expansion kernel on its
    slice every step, and order-free owner-combine collectives (pmin /
    pmax over single-owner contributions) reassemble the replicated beam
    — bitwise the single-device search for any shard count
    (tests/test_corpus_shard.py).  N scales with devices; every device
    sees every query, so per-step latency gains S collectives.

Both layouts reuse the same `topr_merge`-based order-free merges, which is
what makes their shard-count invariance mechanical rather than statistical.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PSpec

from repro.compat import shard_map

from repro.core import labels as L
from repro.core import pools as P
from repro.core import vecstore as VS
from repro.core.grnnd import (
    GRNNDConfig, _pair_requests_chunk, _sorted_requests_chunk)
from repro.core.search import SearchResult, _rescore_merge, medoid, search
from repro.kernels import ops


def _local_round_requests(x, ids_loc, dists_loc, row0, key, cfg: GRNNDConfig):
    """Request generation for a shard of vertices [row0, row0 + n_loc)."""
    n_loc, r = ids_loc.shape
    fn = (_pair_requests_chunk if cfg.order == "disordered"
          else _sorted_requests_chunk)
    rows_local = row0 + jnp.arange(n_loc, dtype=jnp.int32)
    return fn(x, ids_loc, dists_loc, rows_local, key, cfg)


def _filter_to_local(req: P.Requests, row0, n_loc) -> P.Requests:
    """Re-base request destinations to local row indices; drop non-local.

    Self-inserts are dropped HERE, while dst and src are still in the same
    global id space; after re-basing, dst is shard-local and src global, so
    the staging-time dst == src filter would both miss true self-inserts
    and falsely kill genuine requests whose global src happens to equal the
    local row index — downstream staging must run with drop_self=False.
    """
    dst_local = req.dst - row0
    ok = ((req.dst >= 0) & (dst_local >= 0) & (dst_local < n_loc)
          & (req.dst != req.src))
    return P.Requests(
        dst=jnp.where(ok, dst_local, -1),
        src=req.src,
        dist=req.dist,
    )


def make_sharded_builder(
    mesh: Mesh,
    axes: Sequence[str],
    cfg: GRNNDConfig,
    comm: str = "allgather",
):
    """Returns jit-able build_round(x, pool, key) with pools vertex-sharded.

    `axes` are the mesh axis names carrying the vertex shard (e.g.
    ("data",) or ("pod", "data")).  `comm` selects the redirect exchange:
    "allgather" (exact) or "a2a" (bucketed all_to_all, bounded payload).
    """
    axes = tuple(axes)
    vspec = PSpec(axes)          # vertex-sharded arrays
    rspec = PSpec()              # replicated

    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def shard_index():
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def round_body(x, ids_loc, dists_loc, key):
        n_loc, r = ids_loc.shape
        sidx = shard_index()
        row0 = sidx * n_loc
        key = jax.random.fold_in(key, sidx)

        redirect, killed = _local_round_requests(
            x, ids_loc, dists_loc, row0, key, cfg)

        if comm == "allgather":
            red_all = P.Requests(
                dst=jax.lax.all_gather(redirect.dst, axes, tiled=True),
                src=jax.lax.all_gather(redirect.src, axes, tiled=True),
                dist=jax.lax.all_gather(redirect.dist, axes, tiled=True),
            )
        else:  # bucketed all_to_all: fixed cap per (src shard, dst shard)
            # expected redirects/bucket ≈ n_loc · pairs / n_shards; 2x slack.
            cap = max(2 * n_loc * cfg.pairs_per_vertex // max(n_shards, 1), r)
            dst_shard = jnp.where(
                redirect.dst >= 0, redirect.dst // n_loc, n_shards)
            buckets_i = jnp.full((n_shards, cap), -1, jnp.int32)
            buckets_s = jnp.full((n_shards, cap), -1, jnp.int32)
            buckets_d = jnp.full((n_shards, cap), jnp.inf, jnp.float32)
            order = jnp.argsort(dst_shard, stable=True)
            ds = dst_shard[order]
            idx = jnp.arange(ds.shape[0], dtype=jnp.int32)
            is_start = jnp.concatenate([jnp.array([True]), ds[1:] != ds[:-1]])
            seg0 = jax.lax.associative_scan(
                jnp.maximum, jnp.where(is_start, idx, 0))
            rank = idx - seg0
            okk = (rank < cap) & (ds < n_shards)
            row = jnp.where(okk, ds, n_shards)
            buckets_i = buckets_i.at[row, rank].set(
                redirect.dst[order], mode="drop")
            buckets_s = buckets_s.at[row, rank].set(
                redirect.src[order], mode="drop")
            buckets_d = buckets_d.at[row, rank].set(
                redirect.dist[order], mode="drop")
            a2a = functools.partial(
                jax.lax.all_to_all,
                axis_name=axes if len(axes) > 1 else axes[0],
                split_axis=0, concat_axis=0, tiled=True)
            red_all = P.Requests(
                dst=a2a(buckets_i).reshape(-1),
                src=a2a(buckets_s).reshape(-1),
                dist=a2a(buckets_d).reshape(-1),
            )

        # survivors stay aligned in their shard (perf iteration g1):
        # only redirects go through the grouped-request path
        surv_ids = jnp.where(killed, -1, ids_loc)
        surv_dists = jnp.where(killed, jnp.inf, dists_loc)
        local_red = _filter_to_local(red_all, row0, n_loc)
        staged_i, staged_d = P.group_requests(local_red, n_loc, cfg.cap,
                                              drop_self=False)
        ids2 = jnp.concatenate([surv_ids, staged_i], axis=-1)
        d2 = jnp.concatenate([surv_dists, staged_d], axis=-1)
        return ops.topr_merge(ids2, d2, r)

    sharded = shard_map(
        round_body, mesh=mesh,
        in_specs=(rspec, vspec, vspec, rspec),
        out_specs=(vspec, vspec),
        check_vma=False,
    )

    def build_round(x, pool: P.Pool, key) -> P.Pool:
        ids, dists = sharded(x, pool.ids, pool.dists, key)
        return P.Pool(ids, dists)

    return build_round


def sharded_build_graph(
    mesh: Mesh,
    axes: Sequence[str],
    key: jax.Array,
    x: jnp.ndarray,
    cfg: GRNNDConfig,
    comm: str = "allgather",
) -> P.Pool:
    """Full distributed build: init (replicated math, sharded layout) + rounds."""
    n = x.shape[0]
    vshard = NamedSharding(mesh, PSpec(tuple(axes)))
    rshard = NamedSharding(mesh, PSpec())

    x = jax.device_put(x, rshard)
    k_init, k_rounds = jax.random.split(key)
    pool = P.init_random(k_init, x, cfg.s, cfg.r)
    pool = P.Pool(jax.device_put(pool.ids, vshard),
                  jax.device_put(pool.dists, vshard))

    round_fn = jax.jit(make_sharded_builder(mesh, axes, cfg, comm=comm))
    rev_fn = jax.jit(functools.partial(_sharded_reverse, mesh, tuple(axes), cfg))

    for t1 in range(cfg.t1):
        for t2 in range(cfg.t2):
            k = jax.random.fold_in(jax.random.fold_in(k_rounds, t1), t2)
            pool = round_fn(x, pool, k)
        if t1 != cfg.t1 - 1:
            pool = rev_fn(pool)
    return pool


@functools.lru_cache(maxsize=32)
def _sharded_search_fn(mesh: Mesh, axes: tuple, k: int, ef: int,
                       max_steps: int, visited: str, visited_cap: int | None,
                       has_valid: bool, quantized: bool, has_rescore: bool,
                       has_filter: bool, has_map: bool, backend: str,
                       overfetch: int = 4):
    """One jitted shard_map per (mesh, axes, search-config) — cached so
    repeated serving batches reuse the compiled executable instead of
    re-tracing per call.  `has_valid` selects the tombstone-masked variant
    (an extra replicated operand); the static path keeps the original
    maskless trace.  `quantized`/`has_rescore` (the precision ladder,
    DESIGN.md §8) likewise select variants with the store's scale/offset
    and the fp32 rescore tier as extra replicated operands — the store is
    passed FLATTENED (data, scale, offset) so every shard_map operand is a
    plain array and the in_specs stay structural.  `has_filter` (filtered
    search, DESIGN.md §9) selects the predicate variant: the (N, W) vertex
    label words replicate like x, while the (Q, W) per-query allowed words
    shard WITH the queries — and the flag lives in this cache key, so a
    filtered batch can never reuse an unfiltered executable (or vice
    versa).  `has_map` selects the optimized-layout variant (core/
    layout.py): the (N,) inverse permutation replicates like the graph
    and each shard applies it to its own result slice — a per-row gather,
    so shard invariance is untouched.  `overfetch` is the inner search's
    filtered-widening factor — in the cache key because the host-tier
    path (below) pre-widens ef itself and runs with overfetch=1, and the
    two configurations must never share an executable.  `backend` is
    unused in the body but part of the cache key:
    the inner search dispatches kernels at trace time (same contract as
    search._search_impl)."""
    del backend
    qspec = PSpec(axes)
    rspec = PSpec()

    def body(x_r, graph_r, q_loc, entry_r, *extras):
        it = iter(extras)
        x_in = (VS.VectorStore(x_r, next(it), next(it)) if quantized
                else x_r)
        rescore = next(it) if has_rescore else None
        valid = next(it) if has_valid else None
        ids_map = next(it) if has_map else None
        vwords = next(it) if has_filter else None
        fwords = next(it) if has_filter else None
        return search(x_in, graph_r, q_loc, k=k, ef=ef, max_steps=max_steps,
                      entry=entry_r, visited=visited, visited_cap=visited_cap,
                      valid=valid, rescore=rescore,
                      labels=vwords, filter=fwords, ids_map=ids_map,
                      overfetch=overfetch)

    n_extra = 2 * quantized + has_rescore + has_valid + has_map
    in_specs = ((rspec, rspec, qspec, rspec) + (rspec,) * n_extra
                + ((rspec, qspec) if has_filter else ()))
    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=SearchResult(qspec, qspec, qspec),
        check_vma=False,
    ))


def distributed_search(
    mesh: Mesh,
    axes: Sequence[str],
    x,
    graph_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int = 10,
    ef: int = 64,
    max_steps: int = 512,
    entry: jnp.ndarray | None = None,
    visited: str = "dense",
    visited_cap: int | None = None,
    valid: jnp.ndarray | None = None,
    rescore=None,
    labels=None,
    filter=None,
    ids_map: jnp.ndarray | None = None,
) -> SearchResult:
    """Query-sharded beam search over the mesh.

    `axes` are the mesh axis names carrying the query shard.  x and the
    graph are replicated; each shard runs the unmodified `core.search.search`
    on its query slice, so results are bitwise-identical to the single-device
    search for any shard count (no cross-shard state exists).  Queries are
    padded to a multiple of the shard count and the pad rows sliced off.

    `x` may be a VectorStore (the precision ladder): the traversal tier
    replicates at its compact storage width — bf16 halves and int8 quarters
    the per-device footprint of the replicated corpus, which is exactly
    what bounds the serving mesh's maximum N.  `rescore` is the optional
    fp32 exact tier for the post-beam re-rank (core/search.py), also
    replicated.

    `valid` is the dynamic index's tombstone mask (core/dynamic.py).  It is
    replicated here like x and the graph (query sharding); under VERTEX
    sharding (the build layout) the mask shards with the pools instead —
    each shard owns the validity of its own vertex rows.

    `labels`/`filter` are the filtered-search predicate (core/labels.py,
    DESIGN.md §9): the packed vertex words replicate with the corpus; the
    per-query allowed words are a PER-QUERY payload and shard (and pad)
    with the queries.  Filtering stays embarrassingly parallel — the
    route-through beam and result heap are per-query state — so shard
    invariance holds bitwise exactly as in the unfiltered path.

    `ids_map` is the optimized-layout inverse permutation (core/layout.py,
    `OptimizedIndex.inv`), replicated like the graph; each shard maps its
    own returned ids back to original numbering.

    A `vecstore.HostTier` rescore selects the host-cold placement
    (DESIGN.md §13): the tier is never replicated onto the mesh at all —
    the shards traverse without it (full-ef results, ids_map deferred),
    the final ids cross to the host once per batch, and the shared
    `_rescore_merge` program finishes — bitwise the device-resident path.
    """
    axes = tuple(axes)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if visited == "dense":
        visited_cap = None  # unused; normalized to one cache entry (as search())

    if entry is None:
        entry = medoid(x, valid)  # once, replicated — not once per shard

    vwords = fwords = None
    if filter is not None:
        assert labels is not None, "filtered search needs a label store"
        vwords = L.store_words(labels)
        fwords = L.query_words(filter, vwords.shape[1])

    host = VS.is_host(rescore)
    if host:
        # pre-apply the inner search's filtered widening (its default
        # overfetch=4), then run k=ef with overfetch=1 so the shards
        # return the FULL beam/heap the host re-rank needs; rescore and
        # ids_map stay off the mesh and are applied after the gather
        ef_run = max(ef, 4 * k) if filter is not None else ef
        k_run, of_run = ef_run, 1
    else:
        ef_run, k_run, of_run = ef, k, 4

    q_in = queries  # pre-pad queries, for the host-side re-rank
    qn = queries.shape[0]
    pad = (-qn) % n_shards
    if pad:
        queries = jnp.concatenate(
            [queries, jnp.broadcast_to(queries[:1], (pad, queries.shape[1]))])
        if fwords is not None:  # the pad rows' predicates ride along
            fwords = jnp.concatenate(
                [fwords, jnp.broadcast_to(fwords[:1], (pad, fwords.shape[1]))])

    xd, xs, xo = VS.parts(x)
    quantized = xs is not None
    sharded = _sharded_search_fn(mesh, axes, k_run, ef_run, max_steps,
                                 visited, visited_cap, valid is not None,
                                 quantized,
                                 rescore is not None and not host,
                                 filter is not None,
                                 ids_map is not None and not host,
                                 ops.effective_backend(), overfetch=of_run)
    rep = NamedSharding(mesh, PSpec())
    xd = jax.device_put(xd, rep)
    graph_ids = jax.device_put(graph_ids, rep)
    qsharding = NamedSharding(mesh, PSpec(axes))
    queries = jax.device_put(queries, qsharding)
    extra = ()
    if quantized:
        extra += (jax.device_put(xs, rep), jax.device_put(xo, rep))
    if rescore is not None and not host:
        extra += (jax.device_put(rescore, rep),)
    if valid is not None:
        extra += (jax.device_put(valid, rep),)
    if ids_map is not None and not host:
        extra += (jax.device_put(ids_map, rep),)
    if filter is not None:
        extra += (jax.device_put(vwords, rep),
                  jax.device_put(fwords, qsharding))
    res = sharded(xd, graph_ids, queries, entry, *extra)
    if pad:
        res = SearchResult(res.ids[:qn], res.dists[:qn], res.n_expanded[:qn])
    if host:
        rv = rescore.gather(res.ids)                       # (Q, ef, D)
        out_ids, out_dists = _rescore_merge(
            res.ids, rv, jnp.asarray(q_in, jnp.float32), ids_map, k=k)
        return SearchResult(out_ids, out_dists, res.n_expanded)
    return res


@functools.lru_cache(maxsize=32)
def _corpus_search_fn(mesh: Mesh, axes: tuple, n: int, k: int, ef: int,
                      max_steps: int, visited: str, visited_cap: int,
                      has_valid: bool, quantized: bool, has_rescore: bool,
                      has_filter: bool, has_map: bool, backend: str):
    """One jitted shard_map per (mesh, axes, corpus-search config) — the
    corpus-sharded sibling of `_sharded_search_fn`, same caching contract.

    Every O(N) operand (data, graph rows, row offsets, and the optional
    valid / rescore / ids_map / label-word slices) arrives STACKED with a
    leading shard axis and is sharded along `axes` on that axis — each
    device holds a (1, n_loc, ...) slice, which is exactly the local-shard
    view `corpus_shard._corpus_body` expects.  Queries, the entry state,
    and the per-query predicate words replicate: under corpus sharding
    every device walks every query, and the owner-combines inside the body
    (`lax.pmin`/`pmax` over `axes`) reassemble the replicated beam.  The
    body's outputs are identical on all devices (single-owner combines,
    deterministic ops), so the out_specs are replicated.  `n` (the true
    corpus size, distinct from S·n_loc under padding) and `backend` are
    cache-key-only like everywhere else in this module."""
    del backend
    from repro.core.corpus_shard import _corpus_body
    sspec = PSpec(axes)   # stacked shard-major operands, split on axis 0
    rspec = PSpec()

    def body(data, graphs, row0s, q_r, entry_r, entry_row_r, *extras):
        it = iter(extras)
        scale = next(it) if quantized else None
        offset = next(it) if quantized else None
        rescores = next(it) if has_rescore else None
        valids = next(it) if has_valid else None
        entry_valid = next(it) if has_valid else None
        ids_maps = next(it) if has_map else None
        vwords = next(it) if has_filter else None
        entry_words = next(it) if has_filter else None
        fwords = next(it) if has_filter else None
        return _corpus_body(
            data, scale, offset, graphs, row0s, q_r, entry_r, entry_row_r,
            entry_valid, rescores, valids, ids_maps, vwords, entry_words,
            fwords, n=n, k=k, ef=ef, max_steps=max_steps, visited=visited,
            visited_cap=visited_cap, axes=axes)

    in_specs = ((sspec, sspec, sspec, rspec, rspec, rspec)
                + (rspec, rspec) * quantized
                + (sspec,) * has_rescore
                + (sspec, rspec) * has_valid
                + (sspec,) * has_map
                + (sspec, rspec, rspec) * has_filter)
    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=SearchResult(rspec, rspec, rspec),
        check_vma=False,
    ))


def corpus_sharded_search(
    mesh: Mesh,
    axes: Sequence[str],
    index,
    queries: jnp.ndarray,
    *,
    k: int,
    ef: int,
    max_steps: int,
    visited: str,
    visited_cap: int,
    fwords: jnp.ndarray | None,
) -> SearchResult:
    """Run a `corpus_shard.CorpusShardedIndex` over the mesh, one shard per
    device slot along `axes`.

    This is the executor behind `corpus_shard.sharded_search(mesh=...)` —
    arguments arrive normalized (ef widened, visited_cap resolved, the
    filter already packed to (Q, W) words); user code should call that
    wrapper.  The mesh's shard count along `axes` must equal
    `index.n_shards` — the partition is baked into the stacked arrays, not
    re-derived here.
    """
    axes = tuple(axes)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    assert n_shards == index.n_shards, \
        (f"mesh carries {n_shards} shards along {axes} but the index was "
         f"partitioned into {index.n_shards}")

    quantized = index.scale is not None
    fn = _corpus_search_fn(mesh, axes, index.n, k, ef, max_steps, visited,
                           visited_cap, index.valids is not None, quantized,
                           index.rescores is not None, fwords is not None,
                           index.ids_maps is not None,
                           ops.effective_backend())
    sh = NamedSharding(mesh, PSpec(axes))
    rep = NamedSharding(mesh, PSpec())
    args = (jax.device_put(index.data, sh),
            jax.device_put(index.graphs, sh),
            jax.device_put(index.row0s, sh),
            jax.device_put(queries, rep),
            jax.device_put(index.entry, rep),
            jax.device_put(index.entry_row, rep))
    if quantized:
        args += (jax.device_put(index.scale, rep),
                 jax.device_put(index.offset, rep))
    if index.rescores is not None:
        args += (jax.device_put(index.rescores, sh),)
    if index.valids is not None:
        args += (jax.device_put(index.valids, sh),
                 jax.device_put(index.entry_valid, rep))
    if index.ids_maps is not None:
        args += (jax.device_put(index.ids_maps, sh),)
    if fwords is not None:
        args += (jax.device_put(index.vwords, sh),
                 jax.device_put(index.entry_words, rep),
                 jax.device_put(fwords, rep))
    return fn(*args)


def sharded_apply_requests(
    mesh: Mesh,
    axes: Sequence[str],
    pool: P.Pool,
    req: P.Requests,
    cap: int | None = None,
) -> P.Pool:
    """Route a flat insertion-request batch to the owning vertex shards.

    The dynamic-index mutation primitive under the build's vertex-sharded
    layout (DESIGN.md §7): request destinations are GLOBAL vertex ids; each
    shard all-gathers the (tiny) triples, filters to its own row range with
    the same `_filter_to_local` re-basing the build rounds use, and merges
    through the local staging pipeline.  Determinism: identical to the
    single-device `pools.insert_requests` for any shard count, because the
    merge is the same order-free topr_merge dataflow.

    The tombstone mask needs no exchange at all — validity is a per-vertex
    property, so each shard owns the (n_loc,) slice of the mask for its own
    rows and deletes are a purely local scatter.
    """
    axes = tuple(axes)
    vspec = PSpec(axes)
    rspec = PSpec()
    cap = cap if cap is not None else pool.r

    def body(ids_loc, dists_loc, dst, src, dist):
        n_loc, r = ids_loc.shape
        sidx = jnp.int32(0)
        for a in axes:
            sidx = sidx * mesh.shape[a] + jax.lax.axis_index(a)
        row0 = sidx * n_loc
        local = _filter_to_local(P.Requests(dst, src, dist), row0, n_loc)
        staged_i, staged_d = P.group_requests(local, n_loc, cap,
                                              drop_self=False)
        ids2 = jnp.concatenate([ids_loc, staged_i], axis=-1)
        d2 = jnp.concatenate([dists_loc, staged_d], axis=-1)
        return ops.topr_merge(ids2, d2, r)

    ids, dists = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(vspec, vspec, rspec, rspec, rspec),
        out_specs=(vspec, vspec),
        check_vma=False,
    ))(pool.ids, pool.dists, req.dst, req.src, req.dist)
    return P.Pool(ids, dists)


def _sharded_reverse(mesh, axes, cfg: GRNNDConfig, pool: P.Pool) -> P.Pool:
    """Reverse-edge sampling with cross-shard routing (all-gather exchange)."""
    vspec = PSpec(axes)

    def body(ids_loc, dists_loc):
        n_loc, r = ids_loc.shape
        sidx = jnp.int32(0)
        for a in axes:
            sidx = sidx * mesh.shape[a] + jax.lax.axis_index(a)
        row0 = sidx * n_loc

        rows = row0 + jnp.broadcast_to(
            jnp.arange(n_loc, dtype=jnp.int32)[:, None], (n_loc, r))
        deg = jnp.sum(ids_loc >= 0, axis=-1)[:, None]
        take = jnp.ceil(cfg.rho * deg).astype(jnp.int32)
        slot = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32)[None], (n_loc, r))
        sel = (slot < take) & (ids_loc >= 0)

        req = P.Requests(
            dst=jnp.where(sel, ids_loc, -1).reshape(-1),
            src=rows.reshape(-1),
            dist=dists_loc.reshape(-1),
        )
        req_all = P.Requests(
            dst=jax.lax.all_gather(req.dst, axes, tiled=True),
            src=jax.lax.all_gather(req.src, axes, tiled=True),
            dist=jax.lax.all_gather(req.dist, axes, tiled=True),
        )
        local = _filter_to_local(req_all, row0, n_loc)
        staged_i, staged_d = P.group_requests(local, n_loc, cfg.cap,
                                              drop_self=False)
        ids2 = jnp.concatenate([ids_loc, staged_i], axis=-1)
        d2 = jnp.concatenate([dists_loc, staged_d], axis=-1)
        return ops.topr_merge(ids2, d2, r)

    ids, dists = shard_map(
        body, mesh=mesh, in_specs=(vspec, vspec), out_specs=(vspec, vspec),
        check_vma=False,
    )(pool.ids, pool.dists)
    return P.Pool(ids, dists)
