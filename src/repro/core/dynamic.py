"""Dynamic GRNND index: online insert/delete with incremental refinement.

The paper builds a static graph once (Alg. 3) and stops at construction +
query; real serving corpora churn.  `DynamicIndex` wraps a built `Pool` and
keeps it searchable under mutation (DESIGN.md §7):

  * **batched insert** — new vertices get seed neighbors from the existing
    beam search (`core.search.search` over the current graph), emit
    symmetric insertion requests through the same `group_requests` /
    `topr_merge` dataflow as the build, then run a configurable number of
    *localized* propagation rounds: the fused RNG pair evaluation
    (`grnnd._pair_requests_chunk`) over the gathered touched-vertex
    frontier only — O(F·P·D) distance work for F touched vertices instead
    of the full build round's O(N·P·D);
  * **delete via tombstones** — an (N,) validity mask threaded through the
    fused `search_expand` kernel (and its ref.py oracle): a dead vertex is
    excluded from traversal entirely, so queries see the deletion
    immediately while the graph arrays stay put;
  * **compaction** — once tombstones exceed `compact_threshold`, `compact()`
    physically drops dead rows, remaps neighbor ids, and re-sorts pools;
    because tombstones were already invisible to the search, compaction
    preserves search results exactly (tests/test_dynamic.py);
  * **capacity doubling** — vectors, pools, validity, and labels live in
    power-of-two padded buffers, so repeated inserts amortize reallocation
    and the jit caches (seed search, request staging, localized rounds)
    stay warm across growth steps.

External identity is a monotone int64 **label** (returned by `insert`,
accepted by `delete`, reported by `search`): internal slot ids move on
compaction — and, with `DynamicConfig(layout=...)`, on the locality
renumbering passes (core/layout.py, DESIGN.md §10) — labels never do.
Label -> slot lookup is a binary search through an argsort of
`labels[:size]` (without a layout permutation the table is strictly
increasing and the argsort is the identity).

The vertex-sharded distributed variant routes insertion requests to the
owning shard with the same all-gather + local-filter exchange as the build
(`core.distributed.sharded_apply_requests`): construct with `mesh=` and
the symmetric-edge staging of every insert batch runs owner-routed over
the device mesh — identical results to the in-process staging (the same
order-free topr_merge dataflow), proved by tests/test_corpus_shard.py.
The tombstone mask shards with the pools, so DELETE routing is trivially
owner-local: a delete is a scatter into the owning shard's slice of
`valid`, no exchange at all.  `corpus_search()` serves the same index
corpus-sharded (core/corpus_shard.py): each shard owns its slice of the
padded buffers and the result is bitwise `search()` in label space.

With `DynamicConfig(precision=...)` the index keeps a quantized traversal
tier next to the fp32 buffer (DESIGN.md §8): mutation-path distances stay
in the traversal space (frozen quantizer params, round-tripped inserts),
and user-facing searches rescore against the fp32 tier.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import labels as L
from repro.core import layout as LY
from repro.core import pools as P
from repro.core import vecstore as VS
from repro.core.grnnd import GRNNDConfig, _pair_requests_chunk
from repro.core.search import SearchResult, medoid, search
from repro.kernels import ops


class DynamicConfig(NamedTuple):
    """Mutation-path knobs (the build-time knobs stay in GRNNDConfig)."""
    seed_k: int = 8              # seed neighbors per inserted vertex
    seed_ef: int = 64            # beam width of the seed search
    refine_rounds: int = 2       # localized propagation rounds per insert batch
    pairs_per_vertex: int = 32   # sampled slot pairs per frontier vertex
    incoming_cap: int | None = None   # staged insertions per vertex per round
    compact_threshold: float = 0.25   # tombstone fraction that triggers compact()
    min_capacity: int = 64            # smallest padded buffer
    precision: str = "fp32"           # traversal-tier storage (DESIGN.md §8)
    tier: str = "device"              # fp32 rescore-tier placement
                                      # ("device"/"host", DESIGN.md §13);
                                      # "host" pins the fp32 buffer on the
                                      # CPU backend — needs a quantized
                                      # traversal tier to search against
    layout: str | None = None         # locality renumbering ("bfs"/"hub",
                                      # core/layout.py §DESIGN.md §10): slots
                                      # are permuted at construction and
                                      # re-optimized after every compact()


def _pow2_capacity(need: int, floor: int) -> int:
    cap = max(floor, 1)
    while cap < need:
        cap *= 2
    return cap


@functools.partial(jax.jit, static_argnames=("r", "cap"))
def _apply_seed_requests(ids, dists, new_slots, seed_ids, seed_d, *, r, cap):
    """Write the inserted vertices' seed pools and their symmetric edges.

    The new rows' pools are the deduped top-r of the seed search results;
    the reverse direction (new vertex into each seed neighbor's pool) goes
    through the standard request staging — the exact WARP_INSERT-analogue
    dataflow the build uses, so insertion order cannot matter.
    """
    b, sk = seed_ids.shape
    row_i, row_d = ops.topr_merge(seed_ids, seed_d, r)
    ids = ids.at[new_slots].set(row_i)
    dists = dists.at[new_slots].set(row_d)
    req = P.Requests(
        dst=seed_ids.reshape(-1),
        src=jnp.repeat(new_slots, sk),
        dist=seed_d.reshape(-1),
    )
    return P.insert_requests(P.Pool(ids, dists), req, cap=cap)


@functools.partial(jax.jit, static_argnames=("r",))
def _write_seed_rows(ids, dists, new_slots, seed_ids, seed_d, *, r):
    """The row-write half of `_apply_seed_requests`, split out so the
    symmetric-edge half can route through the mesh
    (`distributed.sharded_apply_requests`) on a mesh-constructed index —
    the new rows themselves are a local scatter either way."""
    row_i, row_d = ops.topr_merge(seed_ids, seed_d, r)
    return ids.at[new_slots].set(row_i), dists.at[new_slots].set(row_d)


@functools.partial(jax.jit, static_argnames=("pairs", "cap", "backend"))
def _localized_round(x, ids, dists, frontier, key, *, pairs, cap, backend):
    """One propagation round restricted to the touched-vertex frontier.

    `frontier` is a fixed-size (F,) id vector (-1 = inactive pad); only its
    rows are gathered and pair-evaluated — the O(N·P·D) distance stage of a
    full build round shrinks to O(F·P·D).  Redirects and kills then merge
    through the order-free staging pipeline, so the result is exactly a
    build round in which every non-frontier vertex sampled zero pairs.

    `backend` is unused in the body but part of the jit key (kernels
    dispatch at trace time — same contract as grnnd._build_graph_impl).
    """
    del backend
    n, r = ids.shape
    ok = frontier >= 0
    fr = jnp.clip(frontier, 0)
    ids_c = jnp.where(ok[:, None], ids[fr], -1)
    dists_c = jnp.where(ok[:, None], dists[fr], jnp.inf)
    cfg = GRNNDConfig(r=r, pairs_per_vertex=pairs, order="disordered")
    redirect, killed = _pair_requests_chunk(x, ids_c, dists_c, None, key, cfg)

    # OR-scatter the frontier kill mask back to full rows (duplicate
    # frontier entries combine, exactly like same-round kills in the build)
    kill_full = jnp.zeros((n, r), jnp.int32).at[fr].max(
        (killed & ok[:, None]).astype(jnp.int32), mode="drop").astype(bool)
    surv_ids = jnp.where(kill_full, -1, ids)
    surv_dists = jnp.where(kill_full, jnp.inf, dists)
    staged_i, staged_d = P.group_requests(redirect, n, cap)
    return P.merge_into(P.Pool(surv_ids, surv_dists), staged_i, staged_d)


@jax.jit
def _masked_knn_dists(x, valid, queries):
    d = ops.pairwise_sqdist(queries, x)
    return jnp.where(valid[None, :], d, jnp.inf)


class DynamicIndex:
    """A mutable ANN index over padded device buffers.

    State (capacity C, pool width R):
      x      (C, D) f32   — EXACT-tier vectors; rows >= size are zero pads
      store              — traversal-tier VectorStore over a (C, D) buffer
                           (only when cfg.precision != "fp32"; the CAGRA-
                           style two-tier layout: the compact tier feeds
                           the bandwidth-bound kernels, the fp32 tier
                           feeds rescoring and exact ground truth)
      pool   (C, R)       — neighbor ids/dists (ids are internal slots)
      valid  (C,)   bool  — False for tombstones AND unallocated pads
      labels (C,)   i64   — external label per slot (host array, -1 = pad)
      vlabels (C,)  i32   — optional per-vertex FILTER label (the attribute
                            predicates match on, core/labels.py — distinct
                            from the external-identity `labels` above);
                            -1 = unlabeled/pad, matched by no predicate.
                            The label SPACE (`n_labels`, hence the packed
                            word count W) is frozen at construction, like
                            the quantizer's scale/offset; label values
                            ride through insert, tombstone delete,
                            compact(), and capacity doubling.

    `size` is the allocated prefix (live + tombstoned), `n_live` the live
    count.  `rounds_run` counts localized propagation rounds — the unit the
    <25%-of-rebuild acceptance bound is stated in (ISSUE 3 / fig10).

    Precision notes (DESIGN.md §8): the int8 scale/offset are FROZEN at
    construction (from the initial corpus); inserted vectors quantize with
    the frozen parameters and clip at the build-time range.  Graph edits
    (seed search, staging, localized rounds) run entirely in the
    traversal-tier distance space so pool distances stay consistent;
    user-facing `search()` rescoring happens against the fp32 tier.
    """

    def __init__(self, x: jnp.ndarray, pool: P.Pool,
                 cfg: DynamicConfig = DynamicConfig(),
                 key: jax.Array | None = None,
                 vertex_labels: jnp.ndarray | None = None,
                 n_labels: int | None = None,
                 mesh=None, mesh_axes: tuple = ("data",)):
        # `mesh`: optional device mesh for owner-shard mutation routing
        # (DESIGN.md §11.3) — each insert batch's symmetric-edge staging
        # runs through `distributed.sharded_apply_requests` over the
        # vertex-sharded pools instead of the in-process staging.  Same
        # order-free dataflow, so results are identical for any mesh
        # (tests/test_corpus_shard.py); deletes are owner-local scatters
        # and need no routing.  Power-of-two capacities keep the padded
        # buffers divisible by any power-of-two shard count.
        n, d = x.shape
        assert pool.ids.shape[0] == n
        assert cfg.precision in VS.PRECISIONS, cfg.precision
        assert cfg.tier in VS.PLACEMENTS, cfg.tier
        assert cfg.tier == "device" or cfg.precision != "fp32", \
            "tier='host' needs a quantized traversal tier (the fp32 buffer " \
            "IS the traversal tier at precision='fp32')"
        assert cfg.layout is None or cfg.layout in LY.ORDERS, cfg.layout
        self.cfg = cfg
        self.r = pool.r
        self.size = n
        self.n_live = n
        self.rounds_run = 0
        self._key = key if key is not None else jax.random.PRNGKey(0x0d11)
        self._entry: jnp.ndarray | None = None
        self._mesh = mesh
        self._mesh_axes = tuple(mesh_axes)

        cap = _pow2_capacity(n, cfg.min_capacity)
        self.x = jnp.zeros((cap, d), jnp.float32).at[:n].set(
            x.astype(jnp.float32))
        if cfg.tier == "host":
            # pin the fp32 tier host-side (DESIGN.md §13).  Committed
            # placement is sticky through jnp ops: insert's scatter,
            # capacity-growth pads, and compact's row gather all produce
            # host-committed results, so every later mutation writes the
            # cold tier in place without re-shipping the buffer.
            self.x = jax.device_put(self.x, VS.host_device())
        self._host_tier: VS.HostTier | None = None
        self._host_src = None  # identity of the buffer the cache wraps
        if cfg.precision == "fp32":
            self.store = None
        else:
            enc = VS.encode(self.x[:n], cfg.precision)
            self.store = enc._replace(
                data=jnp.zeros((cap, d), enc.data.dtype).at[:n].set(enc.data))
            # re-base the wrapped pool's distances into the traversal
            # space (§8.3 single-distance-space invariant): the caller's
            # graph may have been built at fp32, and every later mutation
            # — RNG kills, topr_merge ranks — compares against THESE
            # values, so they must be d(x̂_i, x̂_j), not d(x_i, x_j).
            # Recompute per edge (one-time O(N·R·D)) and re-sort.  An
            # empty corpus has no edges to re-base, and the gather kernel
            # cannot slice a 0-row operand — skip it outright.
            if n:
                owners = jnp.repeat(jnp.arange(n, dtype=jnp.int32), pool.r)
                d_t = ops.gather_sqdist(
                    enc, owners, jnp.clip(pool.ids.reshape(-1), 0)
                ).reshape(n, pool.r)
                d_t = jnp.where(pool.ids >= 0, d_t, jnp.inf)
                pool = P.Pool(*ops.topr_merge(pool.ids, d_t, pool.r))
        self.pool = P.Pool(
            ids=jnp.full((cap, self.r), -1, jnp.int32).at[:n].set(pool.ids),
            dists=jnp.full((cap, self.r), jnp.inf, jnp.float32).at[:n].set(
                pool.dists),
        )
        self.valid = jnp.zeros((cap,), bool).at[:n].set(True)
        self.labels = np.full((cap,), -1, np.int64)
        self.labels[:n] = np.arange(n, dtype=np.int64)
        self._next_label = n
        if vertex_labels is None:
            assert n_labels is None, "n_labels without vertex_labels"
            self.n_labels = None
            self.vlabels = None
        else:
            vl = np.asarray(vertex_labels, np.int32)
            assert vl.shape == (n,), vl.shape
            # an empty corpus has no labels to reduce over (the label-
            # carrying twin of the N=0 quantizer guard): the space must
            # then come from n_labels explicitly
            assert n or n_labels is not None, \
                "empty labeled index needs an explicit n_labels"
            self.n_labels = (n_labels if n_labels is not None
                             else int(vl.max()) + 1)
            assert n == 0 or vl.max() < self.n_labels, \
                f"label {vl.max()} outside the frozen space {self.n_labels}"
            self.vlabels = np.full((cap,), -1, np.int32)
            self.vlabels[:n] = vl
        self._vwords: jnp.ndarray | None = None  # packed cache (lazy)
        if cfg.layout is not None:
            self.optimize_layout(cfg.layout)

    # -- bookkeeping ------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.x.shape[0]

    @property
    def tombstone_fraction(self) -> float:
        return 1.0 - self.n_live / max(self.size, 1)

    def __len__(self) -> int:
        return self.n_live

    def _fold_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _tier(self):
        """The traversal-tier dataset the kernels read: the quantized
        store when one exists, the fp32 buffer otherwise."""
        return self.store if self.store is not None else self.x

    def _rescore_tier(self):
        """The rescore operand `search()` passes down: the fp32 buffer
        directly under device placement, a `HostTier` wrapper under host
        placement.  The wrapper is cached by buffer identity — mutations
        replace `self.x` functionally, so a stale cache is impossible and
        `fetched_rows` accumulates across searches between mutations."""
        if self.cfg.tier != "host":
            return self.x
        if self._host_tier is None or self._host_src is not self.x:
            self._host_tier = VS.HostTier(self.x)
            self._host_src = self.x
        return self._host_tier

    def entry(self) -> jnp.ndarray:
        if self._entry is None:
            self._entry = medoid(self._tier(), self.valid)
        return self._entry

    def _ensure_capacity(self, need: int) -> None:
        cap = self.capacity
        if need <= cap:
            return
        new_cap = _pow2_capacity(need, cap)
        grow = new_cap - cap
        self.x = jnp.pad(self.x, ((0, grow), (0, 0)))
        if self.store is not None:
            self.store = self.store._replace(
                data=jnp.pad(self.store.data, ((0, grow), (0, 0))))
        self.pool = P.Pool(
            ids=jnp.pad(self.pool.ids, ((0, grow), (0, 0)),
                        constant_values=-1),
            dists=jnp.pad(self.pool.dists, ((0, grow), (0, 0)),
                          constant_values=jnp.inf),
        )
        self.valid = jnp.pad(self.valid, (0, grow))
        self.labels = np.concatenate(
            [self.labels, np.full((grow,), -1, np.int64)])
        if self.vlabels is not None:
            self.vlabels = np.concatenate(
                [self.vlabels, np.full((grow,), -1, np.int32)])
            self._vwords = None

    # -- layout optimization (core/layout.py, DESIGN.md §10) --------------

    def optimize_layout(self, order: str | None = None) -> None:
        """Renumber slots for access locality (BFS-from-medoid or
        hub-first, `core.layout.order_permutation`).

        A pure internal relabeling: external labels, search results (label
        space, float-exact), and every later mutation are unaffected — the
        permutation is applied consistently to vectors, both precision
        tiers, pools (rows AND the ids inside them), the validity mask,
        and both label tables, and the cached entry vertex is remapped
        rather than recomputed.  Pools keep their width R (mutations need
        the slack), so only the renumbering — not the degree packing — of
        the static `optimize()` pass applies here; inserts land at the
        buffer tail and erode locality until `compact()` re-runs this.
        """
        order = order if order is not None else (self.cfg.layout or "bfs")
        assert order in LY.ORDERS, order
        self.cfg = self.cfg._replace(layout=order)
        size = self.size
        if size <= 1 or self.n_live == 0:
            return
        e = int(self.entry())  # pre-permutation medoid (layout contract)
        perm = LY.order_permutation(
            np.asarray(self.pool.ids[:size]), order, entry=e,
            valid=np.asarray(self.valid[:size]))
        self._apply_slot_permutation(perm)

    def _apply_slot_permutation(self, perm: np.ndarray) -> None:
        """Apply `perm[old_slot] = new_slot` over the allocated prefix
        (pad rows past `size` stay put)."""
        size, cap = self.size, self.capacity
        inv = np.argsort(perm)                              # inv[new] = old
        inv_full = np.concatenate(
            [inv, np.arange(size, cap)]).astype(np.int32)
        perm_full = np.concatenate(
            [perm, np.arange(size, cap)]).astype(np.int32)
        inv_d = jnp.asarray(inv_full)
        perm_d = jnp.asarray(perm_full)

        self.x = self.x[inv_d]
        if self.store is not None:
            # frozen scale/offset ⇒ a pure row gather, stored bytes exact
            self.store = self.store._replace(data=self.store.data[inv_d])
        mapped = jnp.where(self.pool.ids >= 0,
                           perm_d[jnp.clip(self.pool.ids, 0)], -1)
        self.pool = P.Pool(ids=mapped[inv_d], dists=self.pool.dists[inv_d])
        self.valid = self.valid[inv_d]
        self.labels = self.labels[inv_full]
        if self.vlabels is not None:
            self.vlabels = self.vlabels[inv_full]
            self._vwords = None
        if self._entry is not None:
            e = int(self._entry)
            self._entry = (jnp.int32(int(perm[e])) if 0 <= e < size
                           else self._entry)

    # -- mutation ---------------------------------------------------------

    def insert(self, xs: jnp.ndarray,
               vertex_labels: jnp.ndarray | None = None) -> np.ndarray:
        """Insert a batch of vectors; returns their (B,) external labels.

        Seed neighbors come from the existing search beam; the symmetric
        edges and `cfg.refine_rounds` localized propagation rounds then
        stitch the batch into the RNG structure without touching the
        untouched bulk of the graph.

        `vertex_labels` are the batch's (B,) filter labels (only on a
        label-carrying index; values must fit the frozen label space).
        Omitted, the batch lands unlabeled (-1): searchable unfiltered,
        matched by no predicate.
        """
        xs = jnp.asarray(xs, jnp.float32)
        b = xs.shape[0]
        assert b > 0 and xs.shape[1] == self.x.shape[1]
        if vertex_labels is not None:
            assert self.vlabels is not None, \
                "this index was built without vertex labels"
            vertex_labels = np.asarray(vertex_labels, np.int32)
            assert vertex_labels.shape == (b,)
            assert vertex_labels.max() < self.n_labels
        cfg = self.cfg
        cap = cfg.incoming_cap if cfg.incoming_cap is not None else self.r
        seed_k = min(cfg.seed_k, self.r)
        # the batch AS STORED (round-tripped through the frozen quantizer):
        # both seed paths below must produce traversal-space distances
        # (§8.3) — d(x̂_new, x̂_other), never d(x_new, ·)
        xs_t = xs if self.store is None else self.store.requant(xs)

        if self.n_live > 0:
            # seed search runs against the pre-insert graph (tombstones and
            # pad rows are excluded by the validity mask).  NO rescoring:
            # the seed distances become pool entries, so d(x̂_new, x̂_nbr)
            # here equals what a later propagation round would recompute
            # for the same edge.
            res = search(self._tier(), self.pool.ids, xs_t,
                         k=seed_k, ef=max(cfg.seed_ef, seed_k),
                         entry=self.entry(), valid=self.valid)
            seed_ids, seed_d = res.ids, res.dists

        self._ensure_capacity(self.size + b)
        new_slots = jnp.arange(self.size, self.size + b, dtype=jnp.int32)

        if self.n_live == 0:
            # a fully-deleted (or fully-compacted-away) index has no graph
            # to seed from: bootstrap the batch off ITSELF — exact kNN
            # within the batch, mapped to the new slots — so the refinement
            # rounds start from a connected neighborhood instead of leaving
            # the corpus permanently unreachable
            k_boot = min(seed_k, max(b - 1, 1))
            d = ops.pairwise_sqdist(xs_t, xs_t)
            d = d.at[jnp.arange(b), jnp.arange(b)].set(jnp.inf)
            vals, nidx = jax.lax.top_k(-d, k_boot)
            seed_d = -vals
            seed_ids = jnp.where(jnp.isfinite(seed_d), new_slots[nidx], -1)
        self.x = self.x.at[new_slots].set(xs)
        if self.store is not None:
            self.store = self.store.with_rows(new_slots, xs)
        self.valid = self.valid.at[new_slots].set(True)
        if self.vlabels is not None:
            if vertex_labels is not None:
                self.vlabels[self.size:self.size + b] = vertex_labels
            self._vwords = None
        self.labels[self.size:self.size + b] = np.arange(
            self._next_label, self._next_label + b, dtype=np.int64)
        out_labels = self.labels[self.size:self.size + b].copy()
        self._next_label += b

        if self._mesh is None:
            self.pool = _apply_seed_requests(
                self.pool.ids, self.pool.dists, new_slots,
                seed_ids, seed_d, r=self.r, cap=cap)
        else:
            # owner-shard routing (DESIGN.md §11.3): same row writes, then
            # the symmetric edges go through the mesh exchange — request
            # destinations are global slot ids, each shard keeps its own
            from repro.core import distributed as D
            ids2, d2 = _write_seed_rows(
                self.pool.ids, self.pool.dists, new_slots, seed_ids,
                seed_d, r=self.r)
            req = P.Requests(
                dst=seed_ids.reshape(-1),
                src=jnp.repeat(new_slots, seed_ids.shape[1]),
                dist=seed_d.reshape(-1))
            self.pool = D.sharded_apply_requests(
                self._mesh, self._mesh_axes, P.Pool(ids2, d2), req, cap)

        # localized refinement: the frontier is the inserted vertices plus
        # every vertex that received a symmetric edge — a fixed-size vector
        # so repeated equal-sized batches reuse one compiled round
        frontier = jnp.concatenate([new_slots, seed_ids.reshape(-1)])
        backend = ops.effective_backend()
        for _ in range(cfg.refine_rounds):
            self.pool = _localized_round(
                self._tier(), self.pool.ids, self.pool.dists, frontier,
                self._fold_key(), pairs=cfg.pairs_per_vertex, cap=cap,
                backend=backend)
            self.rounds_run += 1

        self.size += b
        self.n_live += b
        self._entry = None
        return out_labels

    def delete(self, labels: np.ndarray) -> int:
        """Tombstone the given external labels; returns the number removed.

        Queries stop returning (and routing through) the vertices
        immediately; the rows are physically reclaimed by `compact()`,
        which auto-triggers once `tombstone_fraction` exceeds
        `cfg.compact_threshold`.  Labels this index never issued raise
        KeyError; already-deleted labels — including ones whose rows a
        past compaction physically reclaimed — are a no-op, so
        at-least-once delete pipelines can retry safely.
        """
        lab = np.atleast_1d(np.asarray(labels, np.int64))
        unknown = (lab < 0) | (lab >= self._next_label)
        if unknown.any():
            raise KeyError(f"unknown labels: {lab[unknown][:8].tolist()}")
        if self.size == 0:
            return 0  # fully-compacted-away index: everything is a no-op
        table = self.labels[:self.size]
        # under a layout permutation (optimize_layout) the table is no
        # longer slot-ordered; binary-search through its argsort (the
        # identity when no permutation ever ran)
        sorter = np.argsort(table, kind="stable")
        pos = np.searchsorted(table, lab, sorter=sorter)
        # issued labels absent from the table were compacted away: no-op
        present = ((pos < self.size)
                   & (table[sorter[np.minimum(pos, self.size - 1)]] == lab))
        slots = np.unique(sorter[pos[present]])
        alive = np.asarray(self.valid)[slots]
        slots = slots[alive]
        if slots.size:
            self.valid = self.valid.at[jnp.asarray(slots)].set(False)
            self.n_live -= int(slots.size)
            # the cached entry survives unless ITS slot was tombstoned:
            # unrelated deletes must not force an O(N·D) medoid recompute,
            # and must not silently reseed later searches from a different
            # vertex (tests/test_dynamic.py regression)
            if self._entry is not None and np.any(slots == int(self._entry)):
                self._entry = None
        if self.tombstone_fraction > self.cfg.compact_threshold:
            self.compact()
        return int(slots.size)

    def compact(self) -> None:
        """Drop tombstoned rows, remap neighbor ids, re-sort pools.

        Tombstones are already invisible to the search (the validity mask
        removes them from traversal), so compaction is a pure relabeling:
        search results — in label space — are preserved exactly.  The
        cached entry vertex is remapped rather than recomputed, keeping
        even float-level trajectories identical.
        """
        size, r = self.size, self.r
        keep = np.asarray(self.valid[:size])
        kept = np.nonzero(keep)[0]
        n_new = int(kept.size)
        new_of_old = np.full((size,), -1, np.int32)
        new_of_old[kept] = np.arange(n_new, dtype=np.int32)

        ids_old = np.asarray(self.pool.ids[:size])[kept]      # (n_new, R)
        d_old = np.asarray(self.pool.dists[:size])[kept]
        nbr_ok = (ids_old >= 0) & keep[np.clip(ids_old, 0, size - 1)]
        mapped = np.where(nbr_ok, new_of_old[np.clip(ids_old, 0, size - 1)],
                          -1).astype(np.int32)
        d_new = np.where(mapped >= 0, d_old, np.inf).astype(np.float32)

        cap = _pow2_capacity(max(n_new, 1), self.cfg.min_capacity)
        d = self.x.shape[1]
        x_new = jnp.zeros((cap, d), jnp.float32).at[:n_new].set(
            self.x[jnp.asarray(kept)])
        if self.store is not None:
            # scale/offset are frozen, so compaction of the traversal tier
            # is a pure row gather — no re-quantization, stored bytes (and
            # therefore every surviving distance) are preserved exactly
            self.store = self.store._replace(
                data=jnp.zeros((cap, d), self.store.data.dtype).at[:n_new]
                .set(self.store.data[jnp.asarray(kept)]))
        # dead neighbors leave holes mid-row: re-establish the sorted,
        # empties-at-end pool invariant with the same merge primitive
        row_i, row_d = ops.topr_merge(jnp.asarray(mapped), jnp.asarray(d_new),
                                      r)
        self.pool = P.Pool(
            ids=jnp.full((cap, r), -1, jnp.int32).at[:n_new].set(row_i),
            dists=jnp.full((cap, r), jnp.inf, jnp.float32).at[:n_new].set(
                row_d),
        )
        self.x = x_new
        self.valid = jnp.zeros((cap,), bool).at[:n_new].set(True)
        labels_new = np.full((cap,), -1, np.int64)
        labels_new[:n_new] = self.labels[:size][keep]
        self.labels = labels_new
        if self.vlabels is not None:
            vl_new = np.full((cap,), -1, np.int32)
            vl_new[:n_new] = self.vlabels[:size][keep]
            self.vlabels = vl_new
            self._vwords = None
        if self._entry is not None:
            e = int(self._entry)
            self._entry = (jnp.int32(new_of_old[e])
                           if 0 <= e < size and new_of_old[e] >= 0 else None)
        self.size = n_new
        self.n_live = n_new
        if self.cfg.layout is not None:
            # re-establish locality over the compacted rows (DESIGN.md
            # §10).  Also exact: the renumbering pass preserves label-space
            # results bit-for-bit (the cached entry is remapped, never
            # recomputed), so compact()'s exactness guarantee survives the
            # extra permutation (tests/test_dynamic.py).
            self.optimize_layout(self.cfg.layout)

    # -- queries ----------------------------------------------------------

    def label_words(self) -> jnp.ndarray:
        """The packed (C, W) vertex label-bitset operand over the FULL
        padded buffer (pads/unlabeled rows are all-zero words, matched by
        no predicate).  Cached; invalidated by insert/compact/growth —
        deletes don't touch it (tombstones are the `valid` mask's job)."""
        assert self.vlabels is not None, \
            "this index was built without vertex labels"
        if self._vwords is None:
            self._vwords = L.pack_ids(jnp.asarray(self.vlabels),
                                      self.n_labels)
        return self._vwords

    def _query_words(self, filter) -> jnp.ndarray:
        assert self.vlabels is not None, \
            "this index was built without vertex labels"
        return L.query_words(filter, L.n_words(self.n_labels))

    def search(self, queries: jnp.ndarray, *, k: int = 10, ef: int = 64,
               max_steps: int = 512, visited: str = "dense",
               visited_cap: int | None = None,
               rescore: bool | None = None,
               filter=None, overfetch: int = 4) -> SearchResult:
        """Beam search over the live graph; result ids are external labels.

        Traversal reads the compact tier; at quantized precision the final
        ef candidates are re-ranked against the fp32 tier (`rescore=None`
        = auto: on iff the traversal tier is quantized).  Under
        `cfg.tier == "host"` that tier lives on the CPU backend and the
        re-rank gathers the ef rows across the boundary — bitwise-equal
        results (DESIGN.md §13, tests/test_tiered.py).

        `filter` is the optional per-query label predicate (core/labels.py
        forms: (Q, W) packed words, (Q, L) bool mask, or (Q,) label ids).
        Tombstoned vertices stay excluded from traversal (valid mask);
        filtered-out LIVE vertices stay traversable but unreturnable
        (route-through) — the two masks compose independently.
        """
        if rescore is None:
            rescore = self.store is not None
        fwords = None if filter is None else self._query_words(filter)
        res = search(self._tier(), self.pool.ids, queries, k=k, ef=ef,
                     max_steps=max_steps, entry=self.entry(),
                     visited=visited, visited_cap=visited_cap,
                     valid=self.valid,
                     rescore=self._rescore_tier() if rescore else None,
                     labels=None if filter is None else self.label_words(),
                     filter=fwords, overfetch=overfetch)
        ids = np.asarray(res.ids)
        lab = np.where(ids >= 0, self.labels[np.clip(ids, 0, None)],
                       np.int64(-1))
        return SearchResult(jnp.asarray(lab), res.dists, res.n_expanded)

    def corpus_search(self, queries: jnp.ndarray, n_shards: int, *,
                      k: int = 10, ef: int = 64, max_steps: int = 512,
                      visited: str = "dense", visited_cap: int | None = None,
                      rescore: bool | None = None, filter=None,
                      overfetch: int = 4, mesh=None,
                      mesh_axes: tuple = ("data",)) -> SearchResult:
        """Corpus-sharded search over this index (core/corpus_shard.py):
        each shard owns 1/S of the padded buffers — vectors, graph rows,
        validity, labels, rescore tier.  Bitwise `search()` in label space
        for any shard count (the invariance tier), across insert, delete,
        and compact — external-label stability is exactly label stability
        of the underlying slot ids under the global→(shard, local) map.

        Re-partitions the current buffers per call (tests/serving demos);
        a production deployment would keep the sharded slices resident and
        update them in place via the owner-routed mutation path.  `mesh`
        runs the shard_map executor; None runs the in-process reference.
        """
        if rescore is None:
            rescore = self.store is not None
        from repro.core import corpus_shard as CS
        idx = CS.shard(
            self._tier(), self.pool.ids, n_shards,
            valid=self.valid,
            rescore=self.x if rescore else None,
            labels=None if filter is None else self.label_words(),
            entry=self.entry(), tier=self.cfg.tier)
        res = CS.sharded_search(
            idx, queries, k=k, ef=ef, max_steps=max_steps, visited=visited,
            visited_cap=visited_cap,
            filter=None if filter is None else self._query_words(filter),
            overfetch=overfetch, mesh=mesh, axes=mesh_axes)
        ids = np.asarray(res.ids)
        lab = np.where(ids >= 0, self.labels[np.clip(ids, 0, None)],
                       np.int64(-1))
        return SearchResult(jnp.asarray(lab), res.dists, res.n_expanded)

    def exact_knn(self, queries: jnp.ndarray, k: int,
                  filter=None) -> jnp.ndarray:
        """Brute-force ground truth over the LIVE corpus, in label space;
        with `filter`, over the live AND allowed corpus (slots past the
        allowed count hold -1) — the filtered-recall denominator."""
        d = _masked_knn_dists(self.x, self.valid, jnp.asarray(queries))
        if filter is not None:
            fwords = self._query_words(filter)
            hit = jnp.any(
                (self.label_words()[None, :, :] & fwords[:, None, :]) != 0,
                axis=-1)
            d = jnp.where(hit, d, jnp.inf)
        vals, idx = jax.lax.top_k(-d, k)
        idx = np.asarray(idx)
        lab = np.where(np.isfinite(np.asarray(-vals)),
                       self.labels[np.clip(idx, 0, None)], np.int64(-1))
        return jnp.asarray(lab)
