"""GRNND: GPU-parallel Relative NN-Descent, adapted to TPU/JAX.

Implements paper Alg. 3/4 as a fully batched, functional pipeline:

  * disordered neighbor propagation (§3.3): every vertex samples
    `pairs_per_vertex` random slot pairs from its read buffer, applies the
    RNG criterion d(n_i, n_j) < max(d(v, n_i), d(v, n_j)) and redirects the
    farther endpoint into the closer endpoint's write buffer;
  * ascending / descending sorted rounds (§4.3 ablation, Fig. 2b/7): the
    faithful parallel port of the sequential UPDATE_NEIGHBORS (Alg. 2) —
    candidates evaluated against already-accepted neighbors in sorted order;
  * the double-buffered pool (§3.5): each round builds the write buffer from
    scratch out of (redirect ∪ survivor) requests, then the buffers swap —
    in functional form, the new Pool value replaces the old;
  * reverse edge sampling (§3.6): between outer iterations, each vertex
    requests insertion of itself into its top ρ·k neighbors' pools.

Batched-vs-sequential semantics note (recorded in DESIGN.md): within one
round all pair evaluations see the same read-buffer snapshot, so a slot
killed by one pair is still visible to other pairs of the same round; kills
are OR-combined at the end of the round.  The GPU version interleaves these
within a warp; both are stochastic explorations of the same criterion and
converge to graphs of equal recall (validated in tests/benchmarks).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pools as P
from repro.core import vecstore as VS
from repro.kernels import ops


class GRNNDConfig(NamedTuple):
    s: int = 16                    # initial random neighbors per vertex
    r: int = 32                    # pool capacity (R)
    t1: int = 3                    # outer iterations (T1)
    t2: int = 4                    # inner rounds (T2)
    rho: float = 0.6               # reverse-edge sampling ratio (ρ)
    pairs_per_vertex: int = 32     # sampled candidate pairs per round
    order: str = "disordered"      # "disordered" | "ascending" | "descending"
    incoming_cap: int | None = None  # staged insertions per vertex per round
    chunk_size: int | None = None    # vertex chunking for bounded memory

    @property
    def cap(self) -> int:
        return self.incoming_cap if self.incoming_cap is not None else self.r


# ---------------------------------------------------------------------------
# Disordered propagation round (Alg. 4)
# ---------------------------------------------------------------------------

def _sample_slot_pairs(key, c, r, p):
    """The shared pair sampling: drawn outside the kernel so every backend
    (pallas / interpret / ref) evaluates the identical pairs."""
    ki, kj = jax.random.split(key)
    si = jax.random.randint(ki, (c, p), 0, r, jnp.int32)
    sj = jax.random.randint(kj, (c, p), 0, r, jnp.int32)
    return si, sj


def _pair_matrices_chunk(x, ids_c, dists_c, key, cfg: GRNNDConfig):
    """Fused pair evaluation for a chunk: (dst, src, dij) (C, P) + kill (C, R).

    The gather -> rowwise_sqdist -> scatter pipeline this used to lower to
    is now one fused op (kernels/rng_round.py): neighbor vectors are pulled
    into VMEM once per vertex, pair distances and the RNG criterion (paper
    eq. 2) are evaluated in-register, and the redirect requests plus kill
    mask come out in a single pass.
    """
    c, r = ids_c.shape
    si, sj = _sample_slot_pairs(key, c, r, cfg.pairs_per_vertex)
    return ops.rng_propagation_round(x, ids_c, dists_c, si, sj)


def _pair_requests_chunk(x, ids_c, dists_c, rows_c, key, cfg: GRNNDConfig):
    """Request-tuple adapter over the fused round (distributed build entry).

    Returns (redirect Requests, kill mask (C, R) bool).
    """
    del rows_c
    dst, src, dij, killed = _pair_matrices_chunk(x, ids_c, dists_c, key, cfg)
    redirect = P.Requests(
        dst=dst.reshape(-1), src=src.reshape(-1), dist=dij.reshape(-1))
    return redirect, killed


# ---------------------------------------------------------------------------
# Sorted round (faithful parallel Alg. 2 — the ascending/descending ablation)
# ---------------------------------------------------------------------------

def _sorted_requests_chunk(x, ids_c, dists_c, rows_c, key, cfg: GRNNDConfig):
    """Alg. 2 applied per vertex on a snapshot, vectorized over the chunk.

    Candidates are processed in ascending (or descending) distance order;
    each is compared against all previously *accepted* neighbors; a conflict
    (d(n, n') <= d(v, n)) rejects the candidate and redirects it to the first
    accepted conflictor.  Returns (redirect Requests, kill mask (C, R)).
    """
    del key
    c, r = ids_c.shape
    sign = 1.0 if cfg.order == "ascending" else -1.0
    order = jnp.argsort(jnp.where(ids_c >= 0, sign * dists_c, jnp.inf), axis=-1)
    ids_o = jnp.take_along_axis(ids_c, order, axis=-1)
    dv_o = jnp.take_along_axis(dists_c, order, axis=-1)
    valid_o = ids_o >= 0

    # pairwise distances among pool members, in sorted-slot space
    # (store-aware gather: rows land dequantized fp32, the same values the
    # fused disordered-round kernel dequantizes in VMEM)
    vecs = VS.take(x, jnp.clip(ids_o, 0).reshape(-1)).reshape(c, r, -1)
    xx = jnp.sum(vecs * vecs, axis=-1)
    g = xx[:, :, None] + xx[:, None, :] - 2.0 * jnp.einsum(
        "crd,csd->crs", vecs, vecs, preferred_element_type=jnp.float32)
    g = jnp.maximum(g, 0.0)

    def step(accepted, i):
        g_i = jax.lax.dynamic_index_in_dim(g, i, axis=1, keepdims=False)  # (C,R)
        dv_i = jax.lax.dynamic_index_in_dim(dv_o, i, axis=1, keepdims=False)
        ok_i = jax.lax.dynamic_index_in_dim(valid_o, i, axis=1, keepdims=False)
        conflict = accepted & (g_i <= dv_i[:, None])                      # (C,R)
        any_conflict = jnp.any(conflict, axis=-1)
        accept_i = ok_i & ~any_conflict
        accepted = accepted.at[:, i].set(accept_i)
        # first accepted conflictor in processing order
        slot_rank = jnp.where(conflict, jnp.arange(r, dtype=jnp.int32)[None, :], r)
        j = jnp.min(slot_rank, axis=-1)                                   # (C,)
        red_dst = jnp.where(
            ok_i & any_conflict,
            jnp.take_along_axis(ids_o, jnp.clip(j, 0, r - 1)[:, None], 1)[:, 0],
            -1,
        )
        red_d = jnp.take_along_axis(
            g_i, jnp.clip(j, 0, r - 1)[:, None], axis=1)[:, 0]
        src_i = jnp.take_along_axis(ids_o, jnp.full((c, 1), i, jnp.int32), 1)[:, 0]
        return accepted, (red_dst, src_i, red_d, accept_i)

    accepted0 = jnp.zeros((c, r), bool)
    accepted, (red_dst, red_src, red_d, accept_seq) = jax.lax.scan(
        step, accepted0, jnp.arange(r, dtype=jnp.int32))

    redirect = P.Requests(
        dst=red_dst.T.reshape(-1),   # scan stacks on axis 0 -> (R, C)
        src=red_src.T.reshape(-1),
        dist=red_d.T.reshape(-1),
    )
    # kill = evaluated-and-rejected slots, mapped back to original slot space
    accepted_orig = jnp.zeros((c, r), bool)
    accepted_orig = accepted_orig.at[
        jnp.broadcast_to(jnp.arange(c)[:, None], (c, r)), order
    ].set(accepted)
    killed = (ids_c >= 0) & ~accepted_orig
    return redirect, killed


# ---------------------------------------------------------------------------
# One inner round: requests -> fresh write buffer -> swap
# ---------------------------------------------------------------------------

def _chunked(pool: P.Pool, key, cfg: GRNNDConfig):
    """Yield the (ids, dists, key) chunking plan, or None for one-shot."""
    n, r = pool.ids.shape
    chunk = cfg.chunk_size
    if chunk is None or n % chunk != 0 or chunk >= n:
        return None
    n_chunks = n // chunk
    return (pool.ids.reshape(n_chunks, chunk, r),
            pool.dists.reshape(n_chunks, chunk, r),
            jax.random.split(key, n_chunks))


def _round_pair_matrices(x, pool: P.Pool, key, cfg: GRNNDConfig):
    """Disordered round over all vertices: fused (N, P) matrices + kill."""
    n, r = pool.ids.shape
    plan = _chunked(pool, key, cfg)
    if plan is None:
        return _pair_matrices_chunk(x, pool.ids, pool.dists, key, cfg)

    ids_ch, dists_ch, keys = plan
    dst, src, dij, killed = jax.lax.map(
        lambda a: _pair_matrices_chunk(x, a[0], a[1], a[2], cfg),
        (ids_ch, dists_ch, keys))
    p = dst.shape[-1]
    return (dst.reshape(n, p), src.reshape(n, p), dij.reshape(n, p),
            killed.reshape(n, r))


def _round_requests(x, pool: P.Pool, key, cfg: GRNNDConfig):
    """Sorted-order round (ascending/descending ablation): flat Requests."""
    n, r = pool.ids.shape
    plan = _chunked(pool, key, cfg)
    if plan is None:
        rows = jnp.arange(n, dtype=jnp.int32)
        return _sorted_requests_chunk(x, pool.ids, pool.dists, rows, key, cfg)

    ids_ch, dists_ch, keys = plan
    chunk = ids_ch.shape[1]
    rows_ch = jnp.arange(n, dtype=jnp.int32).reshape(-1, chunk)
    red, killed = jax.lax.map(
        lambda a: _sorted_requests_chunk(x, a[0], a[1], a[2], a[3], cfg),
        (ids_ch, dists_ch, rows_ch, keys))
    redirect = P.Requests(
        dst=red.dst.reshape(-1), src=red.src.reshape(-1),
        dist=red.dist.reshape(-1))
    return redirect, killed.reshape(n, r)


def update_round(x, pool: P.Pool, key, cfg: GRNNDConfig) -> P.Pool:
    """One UPDATE_NEIGHBORS_PARALLEL round incl. buffer swap (Alg. 4).

    Perf iteration g1 (EXPERIMENTS.md §Perf): survivors (Alg. 4 lines
    11-15) are already per-vertex aligned, so they bypass the request
    sort/scatter entirely — only cross-vertex redirects are grouped.  The
    merged result is the identical top-R of the same union.

    The disordered path consumes the fused kernel's (N, P) matrices
    directly (pools.stage_request_matrix) — no flat (N·P,) Requests
    intermediate; the sorted ablations keep the Requests-tuple path.
    """
    n, r = pool.ids.shape
    if cfg.order == "disordered":
        dst, src, dij, killed = _round_pair_matrices(x, pool, key, cfg)
        staged_i, staged_d = P.stage_request_matrix(dst, src, dij, n, cfg.cap)
    else:
        redirect, killed = _round_requests(x, pool, key, cfg)
        staged_i, staged_d = P.group_requests(redirect, n, cfg.cap)
    surv_ids = jnp.where(killed, -1, pool.ids)
    surv_dists = jnp.where(killed, jnp.inf, pool.dists)
    return P.merge_into(P.Pool(surv_ids, surv_dists), staged_i, staged_d)


# ---------------------------------------------------------------------------
# Reverse edge sampling (§3.6)
# ---------------------------------------------------------------------------

def reverse_edge_round(pool: P.Pool, cfg: GRNNDConfig, rho=None) -> P.Pool:
    """Insert v into the pools of its top ρ·k neighbors (k = live degree).

    Pools are distance-sorted (topr_merge invariant), so "top ρ·k" is a
    per-row prefix of ceil(ρ · degree) slots.
    """
    rho = cfg.rho if rho is None else rho
    n, r = pool.ids.shape
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, r))
    deg = pool.degree()[:, None]                                  # (N, 1)
    take = jnp.ceil(rho * deg).astype(jnp.int32)
    slot = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32)[None, :], (n, r))
    sel = (slot < take) & (pool.ids >= 0)

    req = P.Requests(
        dst=jnp.where(sel, pool.ids, -1).reshape(-1),  # insert INTO neighbor
        src=rows.reshape(-1),                          # ... the owner vertex
        dist=pool.dists.reshape(-1),                   # d symmetric
    )
    return P.insert_requests(pool, req, cap=cfg.cap)


# ---------------------------------------------------------------------------
# Full build (Alg. 3)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def _build_graph_impl(key: jax.Array, x: jnp.ndarray, cfg: GRNNDConfig,
                      t1, t2, rho, backend: str = "auto") -> P.Pool:
    """t1/t2/rho are traced: hyperparameter sweeps share one compilation.

    `backend` is unused in the body but part of the jit key: the kernels
    dispatch on the global ops backend at TRACE time, so without it a
    cached executable from one backend would silently serve another.
    """
    del backend
    k_init, k_rounds = jax.random.split(key)
    pool = P.init_random(k_init, x, cfg.s, cfg.r)

    def outer(t1_i, pool):
        def inner(t2_i, carry):
            pool = carry
            k = jax.random.fold_in(jax.random.fold_in(k_rounds, t1_i), t2_i)
            return update_round(x, pool, k, cfg)

        pool = jax.lax.fori_loop(0, t2, inner, pool)
        pool = jax.lax.cond(
            t1_i != t1 - 1,
            lambda p: reverse_edge_round(p, cfg, rho=rho),
            lambda p: p,
            pool,
        )
        return pool

    return jax.lax.fori_loop(0, t1, outer, pool)


def build_graph(key: jax.Array, x, cfg: GRNNDConfig) -> P.Pool:
    """Construct the ANN graph: init -> T1 x (T2 rounds + reverse sampling).

    `x` is a plain fp32 array or a `core.vecstore.VectorStore` (bf16/int8
    per the precision ladder, DESIGN.md §8): every distance of the build —
    init, fused propagation rounds, sorted ablations — is then computed on
    storage-precision rows (dequantized in-kernel), with fp32 accumulation
    as always.
    """
    static_cfg = cfg._replace(t1=-1, t2=-1, rho=-1.0)  # normalize jit key
    return _build_graph_impl(key, x, static_cfg,
                             jnp.int32(cfg.t1), jnp.int32(cfg.t2),
                             jnp.float32(cfg.rho),
                             backend=ops.effective_backend())


def build_graph_with_stats(key, x, cfg: GRNNDConfig):
    """Un-jitted build that also returns per-round degree/change diagnostics."""
    n = x.shape[0]
    k_init, k_rounds = jax.random.split(key)
    pool = P.init_random(k_init, x, cfg.s, cfg.r)
    stats = []
    for t1 in range(cfg.t1):
        for t2 in range(cfg.t2):
            k = jax.random.fold_in(jax.random.fold_in(k_rounds, t1), t2)
            new_pool = update_round(x, pool, k, cfg)
            changed = jnp.mean((new_pool.ids != pool.ids).astype(jnp.float32))
            stats.append({
                "t1": t1, "t2": t2,
                "mean_degree": float(jnp.mean(new_pool.degree())),
                "frac_changed": float(changed),
            })
            pool = new_pool
        if t1 != cfg.t1 - 1:
            pool = reverse_edge_round(pool, cfg)
    return pool, stats
