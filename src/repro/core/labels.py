"""Label store + packed predicate masks for filtered ANN search (DESIGN.md §9).

Production ANN traffic is rarely unconstrained: multi-tenant serving,
time-windowed corpora, and access-controlled retrieval all ask for the
nearest neighbors *among vectors matching a predicate*.  This module holds
the vertex-side attributes and the query-side predicates that the fused
expansion kernel (kernels/search_expand.py) evaluates per neighbor:

  * **vertex side** — `LabelStore`: a per-vertex int32 label array (one
    categorical label per vertex, -1 = unlabeled) packed into a (N, W)
    int32 **bitset** (bit `l` of the row = "vertex carries label l",
    W = ceil(n_labels / 32) words).  Multi-label vertices pack the same
    way from an (N, L) membership mask (`encode_label_sets`).  The store
    is FROZEN alongside the `VectorStore`: the label-space width W is
    fixed at encode time, exactly like the quantizer's scale/offset, so
    every compiled search variant keys on one static word count.
  * **query side** — a (Q, W) int32 allowed-bitset: query q may *return*
    vertex v iff `any(words[v] & allowed[q])`.  `query_words` normalizes
    the accepted predicate forms — a (Q,) single allowed label id, a
    (Q, L) boolean label mask, or pre-packed (Q, W) words — to the one
    operand layout the kernel sees.

The packed test is pure int32 bitwise math: evaluating it inside the
Pallas kernel and inside the ref.py oracle produces bit-identical flags,
so the filter preserves the kernel/oracle bitwise-parity contract
(tests/test_filtered.py), on every precision rung.

Semantics are ROUTE-THROUGH, not exclude (GGNN's observation that graph
connectivity must survive masking): a filtered-out vertex stays fully
traversable — expanded, scored, inserted into the beam — and is only
masked out of the *result* heap.  Contrast the dynamic index's tombstone
`valid` mask, which removes a vertex from traversal entirely.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

WORD_BITS = 32


def n_words(n_labels: int) -> int:
    """Packed words per bitset row for an `n_labels`-wide label space."""
    return max(1, -(-int(n_labels) // WORD_BITS))


def pack_bits(member: jnp.ndarray) -> jnp.ndarray:
    """(B, L) boolean label-membership mask -> (B, W) packed int32 words.

    Bit `l % 32` of word `l // 32` is membership in label l.  Distinct
    powers of two sum exactly (two's complement makes the l % 32 == 31
    bit land on the int32 sign bit — a valid bit pattern), so the pack is
    deterministic and invertible.
    """
    member = jnp.asarray(member).astype(bool)
    b, l = member.shape
    w = n_words(l)
    pad = w * WORD_BITS - l
    if pad:
        member = jnp.pad(member, ((0, 0), (0, pad)))
    bits = member.reshape(b, w, WORD_BITS).astype(jnp.int32)
    weights = jnp.left_shift(jnp.int32(1),
                             jnp.arange(WORD_BITS, dtype=jnp.int32))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.int32)


def pack_ids(ids: jnp.ndarray, n_labels: int) -> jnp.ndarray:
    """(B,) int32 label ids -> (B, W) one-hot packed words; id -1 -> all
    zeros (an unlabeled vertex / a match-nothing predicate)."""
    ids = jnp.asarray(ids, jnp.int32)
    w = n_words(n_labels)
    word = jnp.clip(ids, 0) // WORD_BITS
    bit = jnp.left_shift(jnp.int32(1),
                         (jnp.clip(ids, 0) % WORD_BITS).astype(jnp.int32))
    rows = jnp.zeros((ids.shape[0], w), jnp.int32)
    rows = rows.at[jnp.arange(ids.shape[0]), word].set(bit)
    return jnp.where((ids >= 0)[:, None], rows, 0)


class LabelStore(NamedTuple):
    """Frozen per-vertex label attributes.

    words  (N, W) int32 — packed label bitset (the kernel operand; one
           (1, W) row is DMA'd per expanded neighbor, on the same per-row
           schedule as the vector and the tombstone bit)
    labels (N,)   int32 — the single label per vertex for stores built
           with `encode_labels`; None for multi-label stores, where the
           bitset is the only representation.
    """
    words: jnp.ndarray
    labels: jnp.ndarray | None = None

    @property
    def n(self) -> int:
        return self.words.shape[0]

    @property
    def w(self) -> int:
        return self.words.shape[1]

    @property
    def capacity(self) -> int:
        """Largest representable label id + 1 (the frozen label space)."""
        return self.w * WORD_BITS


def encode_labels(labels: jnp.ndarray, n_labels: int | None = None
                  ) -> LabelStore:
    """Freeze a (N,) int32 single-label-per-vertex array into a store.

    `n_labels` fixes the label-space width (and therefore W); it defaults
    to max(labels) + 1 but should be given explicitly when the corpus may
    not exercise every label (the dynamic index passes its frozen value).
    """
    labels = jnp.asarray(labels, jnp.int32)
    if n_labels is None:
        n_labels = int(jnp.max(labels)) + 1
    assert n_labels >= 1
    assert int(jnp.max(labels)) < n_labels, \
        f"label {int(jnp.max(labels))} outside the frozen space {n_labels}"
    return LabelStore(pack_ids(labels, n_labels), labels)


def encode_label_sets(member: jnp.ndarray) -> LabelStore:
    """Freeze an (N, L) boolean multi-label membership mask into a store."""
    return LabelStore(pack_bits(member), None)


def store_words(labels) -> jnp.ndarray:
    """The (N, W) kernel operand of a LabelStore or raw packed array."""
    return labels.words if isinstance(labels, LabelStore) else jnp.asarray(
        labels, jnp.int32)


def query_words(filter, w: int) -> jnp.ndarray:
    """Normalize a per-query predicate to the (Q, W) packed operand.

    Accepts (Q, W) pre-packed int32 words (validated against the store
    width), a (Q, L) boolean allowed-label mask (L <= W * 32), or a (Q,)
    int32 single allowed label id per query.
    """
    filter = jnp.asarray(filter)
    if filter.ndim == 1:
        out = pack_ids(filter, w * WORD_BITS)
    elif filter.dtype == bool:
        out = pack_bits(filter)
        assert out.shape[1] <= w, \
            f"predicate label space wider than the store: {out.shape[1]} > {w}"
        if out.shape[1] < w:
            out = jnp.pad(out, ((0, 0), (0, w - out.shape[1])))
    else:
        out = filter.astype(jnp.int32)
        assert out.ndim == 2 and out.shape[1] == w, \
            f"packed predicate must be (Q, {w}), got {out.shape}"
    return out


def allowed_mask(ids: jnp.ndarray, fwords: jnp.ndarray,
                 vwords: jnp.ndarray) -> jnp.ndarray:
    """Per-result predicate evaluation: allowed[q, j] for ids (Q, J) against
    query words (Q, W) and vertex words (N, W); ids < 0 -> False."""
    lw = vwords[jnp.clip(ids, 0)]                       # (Q, J, W)
    hit = jnp.any((lw & fwords[:, None, :]) != 0, axis=-1)
    return (ids >= 0) & hit


def predicate_fraction(ids: jnp.ndarray, fwords: jnp.ndarray,
                       vwords: jnp.ndarray) -> float:
    """Fraction of returned (non -1) ids that satisfy their query's
    predicate — the serving hard invariant (must be 1.0)."""
    ids = jnp.asarray(ids)
    ok = allowed_mask(ids, fwords, vwords)
    n_ret = jnp.sum(ids >= 0)
    return float(jnp.where(n_ret > 0, jnp.sum(ok) / jnp.maximum(n_ret, 1),
                           1.0))


def filtered_brute_force(x, queries: jnp.ndarray, fwords: jnp.ndarray,
                         vwords: jnp.ndarray, k: int,
                         chunk: int = 1024) -> jnp.ndarray:
    """Exact k nearest ALLOWED rows per query; slots beyond the allowed
    count hold -1 (ground truth for filtered recall).  `x` may be a
    VectorStore (ground truth in that rung's dequantized space)."""
    outs = []
    qn = queries.shape[0]
    for lo in range(0, qn, chunk):
        q_c, f_c = queries[lo:lo + chunk], fwords[lo:lo + chunk]
        d = ops.pairwise_sqdist(q_c, x)                     # (c, N)
        hit = jnp.any((vwords[None, :, :] & f_c[:, None, :]) != 0, axis=-1)
        d = jnp.where(hit, d, jnp.inf)
        vals, idx = jax.lax.top_k(-d, k)
        outs.append(jnp.where(jnp.isfinite(vals), idx, -1).astype(jnp.int32))
    return jnp.concatenate(outs, axis=0)


def filtered_recall_at_k(found_ids, true_ids) -> float:
    """Recall against a -1-padded filtered ground truth: the denominator
    counts only real (>= 0) truth entries, so low-selectivity queries with
    fewer than k allowed vertices score out of what actually exists."""
    f = np.asarray(found_ids)
    t = np.asarray(true_ids)
    hits, total = 0, 0
    for row_f, row_t in zip(f, t):
        want = set(row_t[row_t >= 0].tolist())
        hits += len(set(row_f[row_f >= 0].tolist()) & want)
        total += len(want)
    return hits / max(total, 1)


def random_query_filters(key: jax.Array, q: int, n_labels: int,
                         selectivity: float) -> jnp.ndarray:
    """(Q, W) predicates each allowing ~selectivity·n_labels labels (>= 1),
    drawn uniformly without replacement — the benchmark/serving synthetic
    workload (labels uniform over vertices => vertex selectivity tracks
    label selectivity)."""
    m = max(1, round(selectivity * n_labels))
    perm = jax.vmap(lambda k: jax.random.permutation(k, n_labels))(
        jax.random.split(key, q))                        # (Q, n_labels)
    member = jnp.zeros((q, n_labels), bool)
    member = member.at[jnp.arange(q)[:, None], perm[:, :m]].set(True)
    return pack_bits(member)
