"""Post-build graph layout optimization (DESIGN.md §10).

GRNND emits neighbor pools in whatever (N, R) shape and row order
propagation converged them; the fused `search_expand` kernel DMAs those
rows as-is.  CAGRA (PAPERS.md) showed that on exactly this kernel shape,
three index-representation changes buy large constant factors without
touching the search algorithm:

  1. **Degree-fixed packed adjacency** — pools are compacted to a single
     out-degree D: interior -1 holes are squeezed out (stable, so the
     distance-rank edge order of `topr_merge` rows is preserved), rows are
     padded with the -1 sentinel to exactly D, and trailing all-sentinel
     columns beyond the true max degree are dropped.  The kernel's row-DMA
     schedule is unchanged — it already reads fixed-width rows and skips
     sentinels — it just reads D·4 instead of R·4 bytes of ids and gathers
     ≤ D instead of ≤ R vectors per expansion.
  2. **Vertex renumbering for locality** — a permutation places vertices
     that the beam search touches together (graph-BFS levels from the
     medoid entry, or hubs-first by in-degree) at adjacent row indices, so
     neighbor-row gathers hit fewer distinct pages/cache lines.
  3. **Detour-count edge pruning** (optional, `prune=True`) — drop the
     edges CAGRA's §4.2 rank heuristic marks as redundant (an edge v→u is
     detourable when some kept edge v→w has d(v,w) < d(v,u) and
     d(w,u) < d(v,u)); keeps recall at a fraction of the degree.

The permutation contract (what makes (2) safe to ship):

  * `perm[old] = new` maps original vertex ids to optimized row indices;
    `inv = argsort(perm)` maps back.  All index-side state is remapped
    together — VectorStore rows, adjacency rows AND the ids inside them,
    tombstone `valid` masks, rescore tiers, LabelStore words, external
    label tables — and `inv` is handed to the search as `ids_map`, a final
    on-device gather that converts returned ids back to ORIGINAL numbering.
    External callers see identical ids before and after `optimize()`.
  * The entry point is computed on the ORIGINAL arrays and then mapped
    through `perm`.  (Recomputing the medoid after permutation could pick
    a different argmin: fp reductions are not order-invariant.)
  * Renumbering + packing alone is **bitwise-exact**: distances are
    computed row-for-row on the same fp values, `topr_merge` and the
    frontier argmin break ties by position (and positions are preserved —
    packing is a stable compaction whose dropped slots carry +inf, which
    sorts last), visited/dedup logic compares ids for equality only, and
    the dense visited set is positional.  The hashed visited set is
    bitwise-exact at `visited_cap >= N` (identity-mod probing is injective
    there); below that, collisions depend on id values, so renumbering can
    change which re-expansions occur — same contract as the hashed tier
    itself (tests/test_search_parity.py).
  * Pruning (3) intentionally changes results and is OFF by default so
    the equivalence tier (tests/test_layout.py) stays exact; flipping it
    on is an accuracy/speed trade recorded by fig6/EXPERIMENTS §L1.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import labels as L
from repro.core import vecstore as VS
from repro.core.search import SearchResult, medoid
from repro.core.search import search as run_search

ORDERS = ("identity", "hub", "bfs")


# ---------------------------------------------------------------------------
# packed fixed-degree adjacency
# ---------------------------------------------------------------------------

def packed_degree(graph_ids) -> int:
    """Max out-degree over rows — the tightest D that loses no edges."""
    g = np.asarray(graph_ids)
    return max(int(np.max(np.sum(g >= 0, axis=-1), initial=0)), 1)


def pack_adjacency(graph_ids, degree: int | None = None) -> np.ndarray:
    """Compact (N, R) pools to a degree-fixed (N, D) packed adjacency.

    Valid ids are moved to the front of each row with a STABLE compaction
    (preserving the ascending-distance rank order `topr_merge` maintains),
    then rows are -1-padded or rank-truncated to exactly `degree` columns.
    `degree=None` uses the max row degree — lossless, and the default
    `optimize()` uses so the bitwise tier stays exact (truncation drops
    real edges and changes results).
    """
    g = np.ascontiguousarray(np.asarray(graph_ids), dtype=np.int32)
    n, r = g.shape
    if degree is None:
        degree = packed_degree(g)
    assert degree >= 1, degree
    # stable argsort of the "is-sentinel" flag floats valid ids to the
    # front in original (rank) order
    order = np.argsort(g < 0, axis=1, kind="stable")
    packed = np.take_along_axis(g, order, axis=1)
    if degree <= r:
        packed = packed[:, :degree]
    else:
        packed = np.concatenate(
            [packed, np.full((n, degree - r), -1, np.int32)], axis=1)
    return np.ascontiguousarray(packed, dtype=np.int32)


def unpack_adjacency(packed, r: int) -> np.ndarray:
    """Inverse of `pack_adjacency` back to pool width `r` (-1 tail pad).

    Round-trip law (tests/test_layout.py property tier): for any pool row
    with degree ≤ D, `unpack(pack(g, D), R)` equals `pack(g, R)` — the
    canonical packed form at the original width.
    """
    p = np.asarray(packed, dtype=np.int32)
    n, d = p.shape
    assert r >= d, (r, d)
    return np.concatenate([p, np.full((n, r - d), -1, np.int32)], axis=1)


# ---------------------------------------------------------------------------
# vertex orderings
# ---------------------------------------------------------------------------

def order_permutation(graph_ids, order: str, *, entry: int = 0,
                      valid=None) -> np.ndarray:
    """Deterministic locality permutation, `perm[old] = new`.

    "bfs":  breadth-first levels from `entry` (the medoid in `optimize()`),
            within-level ascending original id; unreached / dead vertices
            keep their relative order at the tail.  Neighbor rows the beam
            gathers early land in adjacent pages.
    "hub":  descending in-degree (ties by original id) — high-traffic rows
            first, the CAGRA "frequently visited nodes first" layout; dead
            vertices go last regardless of stale in-edges.
    "identity": no-op (packing only).
    """
    assert order in ORDERS, order
    g = np.asarray(graph_ids)
    n = g.shape[0]
    ok = (np.ones(n, bool) if valid is None
          else np.asarray(valid, dtype=bool).copy())
    if order == "identity":
        return np.arange(n, dtype=np.int64)
    if order == "hub":
        flat = g[(g >= 0) & ok[np.clip(g, 0, n - 1)]]
        indeg = np.bincount(flat, minlength=n)
        # lexsort: last key is primary — live first, then in-degree desc,
        # then original id asc
        new_to_old = np.lexsort((np.arange(n), -indeg, ~ok))
    else:  # bfs
        seen = np.zeros(n, bool)
        levels = []
        entry = int(entry)
        if ok[entry]:
            seen[entry] = True
            frontier = np.array([entry], dtype=np.int64)
        else:
            frontier = np.array([], dtype=np.int64)
        while frontier.size:
            levels.append(frontier)
            nxt = g[frontier].ravel()
            nxt = np.unique(nxt[nxt >= 0])       # sorted ⇒ deterministic
            nxt = nxt[ok[nxt] & ~seen[nxt]]
            seen[nxt] = True
            frontier = nxt
        tail = np.flatnonzero(~seen)             # unreached + dead, in order
        new_to_old = (np.concatenate(levels + [tail]) if levels else tail)
    perm = np.empty(n, dtype=np.int64)
    perm[new_to_old] = np.arange(n, dtype=np.int64)
    return perm


# ---------------------------------------------------------------------------
# detour-count pruning (CAGRA §4.2)
# ---------------------------------------------------------------------------

def detour_counts(ids, dists, *, chunk: int = 512) -> np.ndarray:
    """Per-edge detour counts for rank-sorted pools.

    The edge v→u (rank j in v's row) is detourable via the closer
    neighbor w = ids[v, i] (i < j ⇒ d(v,w) ≤ d(v,u)) when additionally
    d(w,u) < d(v,u): the walk can reach u through w with two strictly
    shorter hops.  Counts how many such w exist per edge.  Runs chunked
    on the host — a one-shot index build step, not a hot path.
    """
    ids = np.asarray(ids)
    dists = np.asarray(dists, dtype=np.float32)
    n, r = ids.shape
    counts = np.zeros((n, r), dtype=np.int32)
    safe = np.clip(ids, 0, n - 1)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        iv = ids[lo:hi]                               # (C, R) v→u ids
        dv = dists[lo:hi]                             # (C, R) d(v, ·)
        # d(w, u) for every (w=rank i, u=rank j) pair: gather w's pool and
        # look u up in it; u absent from w's pool ⇒ treat as far (no detour
        # counted) — conservative, matches CAGRA's pool-local heuristic.
        w_pool_ids = ids[safe[lo:hi]]                 # (C, R, R)
        w_pool_d = dists[safe[lo:hi]]                 # (C, R, R)
        match = w_pool_ids[:, :, None, :] == iv[:, None, :, None]
        # (C, Rw, Ru): min d(w,u) over w's slots naming u (inf if absent)
        dwu = np.where(match, w_pool_d[:, :, None, :], np.inf).min(axis=-1)
        ok_w = (iv >= 0)[:, :, None] & (iv >= 0)[:, None, :]
        ranks = np.arange(r)
        closer = ranks[:, None] < ranks[None, :]      # (Rw, Ru): i < j
        detour = ok_w & closer[None] & (dwu < dv[:, None, :])
        counts[lo:hi] = detour.sum(axis=1, dtype=np.int32)
    return counts


def prune_adjacency(ids, dists, degree: int, *, chunk: int = 512) -> np.ndarray:
    """Keep the `degree` edges per row with the fewest detours.

    Ties break by distance rank (the pool order), and kept edges are
    re-sorted by rank so the packed row stays ascending-distance — the
    invariant every consumer of graph rows assumes.
    """
    ids = np.asarray(ids)
    n, r = ids.shape
    degree = min(degree, r)
    counts = detour_counts(ids, dists, chunk=chunk)
    rank = np.broadcast_to(np.arange(r, dtype=np.int64), (n, r))
    key = counts.astype(np.int64) * (r + 1) + rank
    key = np.where(ids >= 0, key, np.iinfo(np.int64).max)
    keep = np.sort(np.argsort(key, axis=1, kind="stable")[:, :degree], axis=1)
    kept = np.take_along_axis(ids, keep, axis=1).astype(np.int32)
    return pack_adjacency(kept, degree)


# ---------------------------------------------------------------------------
# the optimized index
# ---------------------------------------------------------------------------

class OptimizedIndex(NamedTuple):
    """A search-ready index in optimized layout.

    All array fields live in PERMUTED row order; `inv` (new → old) is the
    `ids_map` handed to the search so returned ids are in the caller's
    original numbering.  `order`, `degree`, `pruned` are provenance.
    """
    x: object                      # fp32 array or VectorStore, rows permuted
    graph_ids: jnp.ndarray         # (N, D) packed adjacency, permuted ids
    entry: jnp.ndarray             # int32 — permuted medoid
    inv: jnp.ndarray               # (N,) int32: inv[new] = old
    perm: jnp.ndarray              # (N,) int32: perm[old] = new
    valid: jnp.ndarray | None      # permuted tombstone mask
    rescore: object | None         # permuted fp32 rescore tier
    vwords: jnp.ndarray | None     # permuted packed label words
    order: str
    degree: int
    pruned: bool

    @property
    def n(self) -> int:
        return int(self.graph_ids.shape[0])

    def search(self, queries, **kw) -> SearchResult:
        """`core.search.search` over the optimized layout; returned ids
        are in ORIGINAL numbering (the inverse permutation is applied
        on-device)."""
        kw.setdefault("entry", self.entry)
        kw.setdefault("valid", self.valid)
        kw.setdefault("rescore", self.rescore)
        if self.vwords is not None:
            kw.setdefault("labels", self.vwords)
        return run_search(self.x, self.graph_ids, queries,
                          ids_map=self.inv, **kw)

    def distributed_search(self, mesh, axes, queries,
                           **kw) -> SearchResult:
        from repro.core import distributed as D
        kw.setdefault("entry", self.entry)
        kw.setdefault("valid", self.valid)
        kw.setdefault("rescore", self.rescore)
        if self.vwords is not None:
            kw.setdefault("labels", self.vwords)
        return D.distributed_search(mesh, axes, self.x, self.graph_ids,
                                    queries, ids_map=self.inv, **kw)


def optimize(
    x,
    graph,
    *,
    order: str = "bfs",
    degree: int | None = None,
    prune: bool = False,
    valid=None,
    rescore=None,
    labels=None,
    entry=None,
    permutation=None,
) -> OptimizedIndex:
    """Build an `OptimizedIndex` from a built graph (the post-build pass).

    `graph` is a `pools.Pool` or a raw (N, R) id array (pruning needs the
    Pool — it reads the rank distances).  `degree=None` packs to the max
    row degree (lossless); an explicit smaller `degree` truncates by rank,
    or — with `prune=True` — by CAGRA detour count.  `order` picks the
    renumbering ("bfs" | "hub" | "identity"); `permutation` overrides it
    with an explicit old→new map (the property-test hook).  `labels` may
    be a LabelStore or packed (N, W) words; `entry` defaults to the medoid
    computed on the ORIGINAL arrays (see the permutation contract above).
    """
    ids = np.asarray(graph.ids if hasattr(graph, "ids") else graph)
    n = ids.shape[0]
    assert (VS.parts(x)[0]).shape[0] == n, "x rows must match graph rows"

    if entry is None:
        entry = medoid(x, None if valid is None else jnp.asarray(valid))
    e_old = int(entry)

    if prune:
        assert hasattr(graph, "dists"), \
            "detour pruning needs a Pool (rank distances)"
        d = degree if degree is not None else packed_degree(ids)
        packed = prune_adjacency(ids, graph.dists, d)
    else:
        packed = pack_adjacency(ids, degree)

    if permutation is not None:
        perm = np.asarray(permutation, dtype=np.int64)
        assert perm.shape == (n,)
        chk = np.zeros(n, bool)
        chk[perm] = True
        assert chk.all(), "permutation must be a bijection on [0, N)"
    else:
        perm = order_permutation(packed, order, entry=e_old, valid=valid)
    order_tag = "custom" if permutation is not None else order

    inv = np.argsort(perm)                       # inv[new] = old
    perm_d = jnp.asarray(perm.astype(np.int32))
    inv_d = jnp.asarray(inv.astype(np.int32))

    g = jnp.asarray(packed)
    g = jnp.where(g >= 0, perm_d[jnp.clip(g, 0)], -1)[inv_d]

    xd, xs, xo = VS.parts(x)
    xp = (VS.VectorStore(jnp.asarray(xd)[inv_d], xs, xo) if xs is not None
          else jnp.asarray(xd)[inv_d])
    valid_p = None if valid is None else jnp.asarray(valid)[inv_d]
    rescore_p = None
    if rescore is not None:
        rd, rs, ro = VS.parts(rescore)
        rescore_p = (VS.VectorStore(jnp.asarray(rd)[inv_d], rs, ro)
                     if rs is not None else jnp.asarray(rd)[inv_d])
    vwords_p = None
    if labels is not None:
        vwords_p = L.store_words(labels)[inv_d]

    return OptimizedIndex(
        x=xp, graph_ids=g, entry=perm_d[e_old].astype(jnp.int32),
        inv=inv_d, perm=perm_d, valid=valid_p, rescore=rescore_p,
        vwords=vwords_p, order=order_tag, degree=int(g.shape[1]),
        pruned=bool(prune))
