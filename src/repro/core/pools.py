"""Fixed-capacity, double-buffered neighbor pools (GRNND §3.5) — functional.

A pool is a pair of arrays over all N vertices:

    pool_ids   (N, R) int32    — neighbor vertex ids, -1 marks an empty slot
    pool_dists (N, R) float32  — squared L2 distance to the owning vertex,
                                 +inf marks an empty slot

The GPU version holds two static R-slot buffers per vertex and swaps
pointers; here the double buffer is value semantics (the update produces new
arrays) and the "clear" is re-initialization to sentinels.  The GPU's atomic
WARP_INSERT becomes a deterministic two-stage dataflow:

  1. group_requests: all (dst, src, dist) insertion requests of a round are
     lex-sorted (dst-major, dist-minor), capacity-capped per destination
     segment, and scattered into a per-vertex staging buffer — this replaces
     inter-warp atomics with one sort + one scatter;
  2. topr_merge: per vertex, pool ∪ staging is deduped and the R closest
     survive — this replaces ballot dedup + replace-farthest-if-closer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import vecstore as VS
from repro.kernels import ops


class Pool(NamedTuple):
    ids: jnp.ndarray    # (N, R) int32
    dists: jnp.ndarray  # (N, R) float32

    @property
    def n(self) -> int:
        return self.ids.shape[0]

    @property
    def r(self) -> int:
        return self.ids.shape[1]

    def degree(self) -> jnp.ndarray:
        return jnp.sum(self.ids >= 0, axis=-1)


def empty_pool(n: int, r: int) -> Pool:
    return Pool(
        ids=jnp.full((n, r), -1, jnp.int32),
        dists=jnp.full((n, r), jnp.inf, jnp.float32),
    )


def init_random(key: jax.Array, x, s: int, r: int) -> Pool:
    """Random S-NN initialization (paper Alg. 3 lines 3-5).

    Each vertex receives S distinct-ish random neighbors (self-edges are
    rerolled by offset), with true distances, placed in an R-capacity pool.
    `x` may be a VectorStore (the precision ladder): init distances are
    then computed in the same storage-precision distance space as every
    later round, so the pool's distance invariants stay consistent.
    """
    n, _ = x.shape
    assert s <= r
    raw = jax.random.randint(key, (n, s), 0, n - 1, jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    # map the range [0, n-1) onto [0, n) \ {v}: anything >= v shifts up by 1
    ids = jnp.where(raw >= rows, raw + 1, raw)
    dists = _owner_dists(x, rows[:, 0], ids)
    ids = jnp.pad(ids, ((0, 0), (0, r - s)), constant_values=-1)
    dists = jnp.pad(dists, ((0, 0), (0, r - s)), constant_values=jnp.inf)
    # dedup (randint can repeat) + sort by distance
    return Pool(*ops.topr_merge(ids, dists, r))


def _owner_dists(x, owners: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """d(x[owner], x[id]) for an (B, K) id matrix; invalid ids -> +inf.

    Store-aware: rows are gathered dequantized (fp32), so the rowwise
    kernel below sees the same values the fused build kernels dequantize
    in VMEM.
    """
    b, k = ids.shape
    safe = jnp.clip(ids, 0)
    xv = VS.take(x, owners)                                  # (B, D)
    nv = VS.take(x, safe.reshape(-1)).reshape(b, k, -1)      # (B, K, D)
    d = ops.rowwise_sqdist(
        jnp.repeat(xv, k, axis=0).reshape(b * k, -1),
        nv.reshape(b * k, -1),
    ).reshape(b, k)
    return jnp.where(ids >= 0, d, jnp.inf)


class Requests(NamedTuple):
    """A flat batch of insertion requests: put `src` into `dst`'s pool."""
    dst: jnp.ndarray   # (M,) int32, -1 = inactive
    src: jnp.ndarray   # (M,) int32
    dist: jnp.ndarray  # (M,) float32  d(dst, src)


def concat_requests(*reqs: Requests) -> Requests:
    return Requests(
        dst=jnp.concatenate([r.dst for r in reqs]),
        src=jnp.concatenate([r.src for r in reqs]),
        dist=jnp.concatenate([r.dist for r in reqs]),
    )


def group_requests(req: Requests, n: int, cap: int,
                   drop_self: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stage a flat Requests batch into per-destination buffers.

    `drop_self=False` skips the dst == src self-insert filter — for the
    distributed paths, whose destinations are RE-BASED to shard-local row
    indices while sources stay global: comparing those spaces would both
    miss true self-inserts and drop genuine cross-space coincidences, so
    the self filter runs in global space (`distributed._filter_to_local`)
    before re-basing instead.
    """
    return _stage(req.dst, req.src, req.dist, n, cap, drop_self=drop_self)


def stage_request_matrix(
    dst: jnp.ndarray, src: jnp.ndarray, dist: jnp.ndarray, n: int, cap: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stage the fused round's (N, P) request matrices: -> ids/dists (N, cap).

    This is the direct consumer of `ops.rng_propagation_round` output —
    the row-major flatten below is a metadata-only reshape, so no (N·P,)
    request copies (and no Requests tuple) are materialized between the
    kernel and the sort/scatter staging pipeline.
    """
    return _stage(dst.reshape(-1), src.reshape(-1), dist.reshape(-1), n, cap)


def _stage(dst, src_in, dist_in, n: int, cap: int,
           drop_self: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stage requests into per-destination buffers: -> ids/dists (N, cap).

    Deterministic replacement for atomic concurrent insertion: requests are
    ordered dist-minor / dst-major with two stable sorts, ranked within their
    destination segment, and the first `cap` per destination scattered.
    Self-inserts (dst == src; only meaningful when both live in the same id
    space — see group_requests) and inactive requests are dropped.
    """
    if drop_self:
        dst = jnp.where(dst == src_in, -1, dst)

    # dedup identical (dst, src) requests so duplicates cannot crowd out
    # distinct candidates at the capacity rank below: sort src-minor /
    # dst-major, invalidate repeats.
    o1 = jnp.argsort(src_in, stable=True)
    o2 = jnp.argsort(jnp.where(dst >= 0, dst, n)[o1], stable=True)
    dperm = o1[o2]
    dst_p, src_p = dst[dperm], src_in[dperm]
    dup = jnp.concatenate([
        jnp.array([False]),
        (dst_p[1:] == dst_p[:-1]) & (src_p[1:] == src_p[:-1]) & (dst_p[1:] >= 0),
    ])
    dst = dst.at[dperm].set(jnp.where(dup, -1, dst_p))

    dist = jnp.where(dst >= 0, dist_in, jnp.inf)
    dst_key = jnp.where(dst >= 0, dst, n)  # inactive sorts to the end

    # stable composed sort: dist-minor then dst-major
    order1 = jnp.argsort(dist, stable=True)
    dst_s = dst_key[order1]
    order2 = jnp.argsort(dst_s, stable=True)
    perm = order1[order2]

    dst_s = dst_key[perm]
    src_s = src_in[perm]
    dist_s = dist[perm]

    m = dst_s.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.array([True]), dst_s[1:] != dst_s[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank = idx - seg_start

    keep = (rank < cap) & (dst_s < n)
    slot_dst = jnp.where(keep, dst_s, n)  # OOB rows dropped by mode="drop"
    staged_ids = jnp.full((n, cap), -1, jnp.int32)
    staged_dists = jnp.full((n, cap), jnp.inf, jnp.float32)
    staged_ids = staged_ids.at[slot_dst, rank].set(src_s, mode="drop")
    staged_dists = staged_dists.at[slot_dst, rank].set(dist_s, mode="drop")
    return staged_ids, staged_dists


def merge_into(pool: Pool, cand_ids: jnp.ndarray, cand_dists: jnp.ndarray) -> Pool:
    """pool ∪ candidates -> R closest unique (the WARP_INSERT analogue)."""
    ids = jnp.concatenate([pool.ids, cand_ids], axis=-1)
    dists = jnp.concatenate([pool.dists, cand_dists], axis=-1)
    return Pool(*ops.topr_merge(ids, dists, pool.r))


def insert_requests(pool: Pool, req: Requests, cap: int | None = None) -> Pool:
    """Group a request batch and merge it into the pool (both stages)."""
    cap = cap if cap is not None else pool.r
    staged_ids, staged_dists = group_requests(req, pool.n, cap)
    return merge_into(pool, staged_ids, staged_dists)


def build_requests_into_empty(
    n: int, r: int, req: Requests, cap: int | None = None
) -> Pool:
    """Materialize a fresh pool (the cleared write buffer) from requests only."""
    cap = cap if cap is not None else r
    staged_ids, staged_dists = group_requests(req, n, max(cap, r))
    return Pool(*ops.topr_merge(staged_ids, staged_dists, r))
