"""Ground-truth kNN (brute force, chunked) and Recall@k evaluation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def brute_force_knn(
    x: jnp.ndarray, queries: jnp.ndarray, k: int, chunk: int = 1024
) -> jnp.ndarray:
    """Exact k nearest dataset rows per query (squared L2), chunked over Q."""
    outs = []
    qn = queries.shape[0]
    for lo in range(0, qn, chunk):
        d = ops.pairwise_sqdist(queries[lo:lo + chunk], x)
        idx = jax.lax.top_k(-d, k)[1]
        outs.append(idx)
    return jnp.concatenate(outs, axis=0).astype(jnp.int32)


def recall_at_k(found_ids: jnp.ndarray, true_ids: jnp.ndarray) -> float:
    """Fraction of true k-NN retrieved (order-insensitive). found (Q,k), true (Q,k)."""
    f = np.asarray(found_ids)
    t = np.asarray(true_ids)
    hits = 0
    for row_f, row_t in zip(f, t):
        hits += len(set(row_f[row_f >= 0].tolist()) & set(row_t.tolist()))
    return hits / t.size
