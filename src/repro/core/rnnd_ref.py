"""Sequential RNN-Descent (paper Alg. 1 + 2) — the CPU baseline / oracle.

Faithful numpy port of Ono & Matsui's RNN-Descent as described in GRNND §2.2:
vertices are processed one at a time with immediate writes, candidates are
evaluated in ascending order against the already-accepted set, rejected
candidates are redirected to the conflicting accepted neighbor, and full
reverse edges are inserted between outer iterations.

Deliberately unoptimized; used (a) as the CPU baseline in the Fig-5 analogue
benchmark, and (b) as the quality oracle that the parallel GRNND build must
match in recall at equal parameters.
"""
from __future__ import annotations

import numpy as np


def _sqdist(a: np.ndarray, b: np.ndarray) -> float:
    d = a - b
    return float(d @ d)


def build_graph_ref(
    x: np.ndarray,
    s: int = 16,
    r: int = 32,
    t1: int = 3,
    t2: int = 4,
    seed: int = 0,
) -> list[list[int]]:
    """Returns adjacency lists (each sorted ascending by distance, len <= r)."""
    x = np.asarray(x, dtype=np.float32)
    n = x.shape[0]
    rng = np.random.default_rng(seed)

    # --- INITIALIZATION: S random neighbors per vertex ---
    pools: list[dict[int, float]] = []
    for v in range(n):
        cand = rng.choice(n - 1, size=min(s, n - 1), replace=False)
        cand = np.where(cand >= v, cand + 1, cand)
        pools.append({int(c): _sqdist(x[v], x[c]) for c in cand})

    for outer in range(t1):
        for _ in range(t2):
            for v in range(n):
                # Alg. 2: sort by distance, dedup (dict already unique), top R
                items = sorted(pools[v].items(), key=lambda kv: kv[1])[:r]
                accepted: list[tuple[int, float]] = []
                for nid, dvn in items:
                    valid = True
                    for aid, _ in accepted:
                        dnn = _sqdist(x[nid], x[aid])
                        if dnn <= dvn:
                            valid = False
                            # redirect n -> N_{n'} (immediate write)
                            pa = pools[aid]
                            if nid != aid and nid not in pa:
                                pa[nid] = dnn
                                if len(pa) > 2 * r:  # soft cap like dynamic pool
                                    worst = max(pa, key=pa.get)
                                    del pa[worst]
                            break
                    if valid:
                        accepted.append((nid, dvn))
                pools[v] = dict(accepted)

        if outer != t1 - 1:
            # ADD_REVERSE_EDGES (full, the sequential algorithm's ρ = 1)
            snapshot = [list(p.items()) for p in pools]
            for v in range(n):
                for nid, dvn in snapshot[v]:
                    pn = pools[nid]
                    if v != nid and v not in pn:
                        pn[v] = dvn
                        if len(pn) > 2 * r:
                            worst = max(pn, key=pn.get)
                            del pn[worst]

    return [
        [nid for nid, _ in sorted(p.items(), key=lambda kv: kv[1])[:r]]
        for p in pools
    ]


def adjacency_to_pool_arrays(adj: list[list[int]], r: int):
    """Convert ref adjacency lists to the (ids, dists-less) array layout."""
    n = len(adj)
    ids = np.full((n, r), -1, np.int32)
    for v, lst in enumerate(adj):
        ids[v, : len(lst[:r])] = lst[:r]
    return ids
