"""Batched best-first graph search over a GRNND/RNN-Descent graph.

Standard greedy beam search (the "fixed search algorithm" the paper uses to
compare indices): a candidate list of size `ef` per query, expand the closest
unexpanded candidate, push its unvisited neighbors, stop when every list
entry is expanded.  Fully batched over queries with jax.lax.while_loop; the
visited set is a dense (Q, N) bitmask (exact; a hashed variant would replace
it at billion scale — see DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


class SearchResult(NamedTuple):
    ids: jnp.ndarray     # (Q, k) int32
    dists: jnp.ndarray   # (Q, k) float32
    n_expanded: jnp.ndarray  # (Q,) int32 — distance computations proxy


def medoid(x: jnp.ndarray) -> jnp.ndarray:
    """Entry point: vertex nearest to the dataset centroid."""
    c = jnp.mean(x, axis=0, keepdims=True)
    return jnp.argmin(ops.pairwise_sqdist(c, x)[0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "ef", "max_steps"))
def search(
    x: jnp.ndarray,
    graph_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int = 10,
    ef: int = 64,
    max_steps: int = 512,
    entry: jnp.ndarray | None = None,
) -> SearchResult:
    """Search the graph for the k nearest vertices to each query row."""
    n, r = graph_ids.shape
    q = queries.shape[0]
    assert ef >= k
    if entry is None:
        entry = medoid(x)

    qrows = jnp.arange(q, dtype=jnp.int32)

    d_entry = ops.rowwise_sqdist(queries, jnp.broadcast_to(x[entry], queries.shape))
    cand_ids = jnp.full((q, ef), -1, jnp.int32).at[:, 0].set(entry)
    cand_dists = jnp.full((q, ef), jnp.inf, jnp.float32).at[:, 0].set(d_entry)
    expanded = jnp.zeros((q, ef), bool)
    visited = jnp.zeros((q, n), bool).at[:, entry].set(True)
    n_exp = jnp.zeros((q,), jnp.int32)

    def cond(state):
        cand_ids, cand_dists, expanded, visited, n_exp, steps = state
        frontier = (cand_ids >= 0) & ~expanded
        return (steps < max_steps) & jnp.any(frontier)

    def body(state):
        cand_ids, cand_dists, expanded, visited, n_exp, steps = state
        frontier_d = jnp.where((cand_ids >= 0) & ~expanded, cand_dists, jnp.inf)
        sel = jnp.argmin(frontier_d, axis=-1)                      # (Q,)
        active = jnp.isfinite(jnp.min(frontier_d, axis=-1))        # (Q,)
        sel_id = cand_ids[qrows, sel]
        expanded = expanded.at[qrows, sel].set(True)

        nbrs = graph_ids[jnp.clip(sel_id, 0)]                      # (Q, R)
        nbrs = jnp.where(active[:, None] & (nbrs >= 0), nbrs, -1)
        seen = visited[qrows[:, None], jnp.clip(nbrs, 0)]
        fresh = (nbrs >= 0) & ~seen
        visited = visited.at[qrows[:, None], jnp.clip(nbrs, 0)].max(fresh)

        # distances query -> neighbor vectors
        nv = x[jnp.clip(nbrs, 0).reshape(-1)].reshape(q, r, -1)
        dq = ops.rowwise_sqdist(
            jnp.repeat(queries, r, axis=0).reshape(q * r, -1),
            nv.reshape(q * r, -1),
        ).reshape(q, r)
        dq = jnp.where(fresh, dq, jnp.inf)
        n_exp = n_exp + jnp.sum(fresh, axis=-1, dtype=jnp.int32)

        # merge: keep ef best of (candidate list + fresh neighbors);
        # ids are unique by construction (visited filter), so plain
        # sort-merge suffices — but reuse topr_merge for the dedup guarantee.
        all_ids = jnp.concatenate([cand_ids, jnp.where(fresh, nbrs, -1)], axis=-1)
        all_d = jnp.concatenate([cand_dists, dq], axis=-1)
        all_exp = jnp.concatenate([expanded, jnp.zeros((q, r), bool)], axis=-1)
        order = jnp.argsort(jnp.where(all_ids >= 0, all_d, jnp.inf), axis=-1)
        all_ids = jnp.take_along_axis(all_ids, order, axis=-1)
        all_d = jnp.take_along_axis(all_d, order, axis=-1)
        all_exp = jnp.take_along_axis(all_exp, order, axis=-1)
        cand_ids = all_ids[:, :ef]
        cand_dists = all_d[:, :ef]
        expanded = all_exp[:, :ef] | (cand_ids < 0)

        return cand_ids, cand_dists, expanded, visited, n_exp, steps + 1

    state = (cand_ids, cand_dists, expanded, visited, n_exp, jnp.int32(0))
    cand_ids, cand_dists, expanded, visited, n_exp, _ = jax.lax.while_loop(
        cond, body, state)
    return SearchResult(cand_ids[:, :k], cand_dists[:, :k], n_exp)
