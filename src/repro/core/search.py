"""Batched best-first graph search over a GRNND/RNN-Descent graph.

Standard greedy beam search (the "fixed search algorithm" the paper uses to
compare indices): a candidate list of size `ef` per query, expand the closest
unexpanded candidate, push its unvisited neighbors, stop when every list
entry is expanded.  Fully batched over queries with jax.lax.while_loop.

The production pieces (DESIGN.md §6):

  * the expansion step — gather the selected vertex's R neighbor vectors,
    compute query->neighbor distances, probe the visited set — is one fused
    op (`ops.search_expand`, kernels/search_expand.py) with a ref.py oracle;
  * the visited set is selectable: `visited="dense"` keeps the exact (Q, N)
    bitmask (right at reproduction scale), `visited="hashed"` replaces it
    with a fixed-size per-query open-addressed table of `visited_cap` int32
    slots, making search memory O(Q·H) independent of N.  Collisions and
    capacity misses only cause harmless re-expansions, never false skips;
    with `visited_cap >= N` the hashed path is provably collision-free and
    bitwise-identical to the dense reference (tests/test_search_parity.py);
  * the per-step beam merge is the deduplicating `ops.topr_merge` primitive
    the build path already uses — no full (Q, ef+R) argsort per step, and
    re-entering duplicates (possible under hash capacity misses) are
    absorbed instead of crowding the beam;
  * filtered search (`labels=`/`filter=`, core/labels.py, DESIGN.md §9)
    evaluates a per-query label predicate inside the same fused expansion
    op and accumulates predicate-passing vertices in a separate result
    heap — the beam itself stays unfiltered (route-through), so graph
    connectivity survives masking.

Query sharding over a device mesh lives in `core.distributed.
distributed_search` (x and graph replicated, queries sharded — searches are
embarrassingly parallel over queries).  CORPUS sharding — each device owns
1/S of the vectors/graph/labels/rescore tier and this loop's per-step
gathers become shard-local kernel calls plus order-free owner-combines —
lives in `core.corpus_shard` (DESIGN.md §11); that module mirrors this
loop line-for-line and is locked to it by a bitwise invariance tier
(tests/test_corpus_shard.py), so semantic changes here must land there in
the same commit.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import labels as L
from repro.core import vecstore as VS
from repro.kernels import ops
from repro.kernels.ref import visited_probe_positions


class SearchResult(NamedTuple):
    ids: jnp.ndarray     # (Q, k) int32
    dists: jnp.ndarray   # (Q, k) float32
    n_expanded: jnp.ndarray  # (Q,) int32 — distance computations proxy


def medoid(x, valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Entry point: vertex nearest to the dataset centroid.

    With a `valid` mask (dynamic index: tombstones + unallocated padded
    rows, core/dynamic.py), both the centroid and the argmin are restricted
    to live rows, so the entry is always a live vertex.  `x` may be a
    VectorStore: the centroid is taken over the dequantized corpus (a
    one-shot startup computation, not a hot path) so the entry choice
    matches what the traversal distances will see.
    """
    if valid is None:
        c = jnp.mean(VS.dequant(x), axis=0, keepdims=True)
        return jnp.argmin(ops.pairwise_sqdist(c, x)[0]).astype(jnp.int32)
    v = valid.astype(jnp.float32)
    c = (jnp.sum(VS.dequant(x) * v[:, None], axis=0)
         / jnp.maximum(jnp.sum(v), 1.0))[None, :]
    d = jnp.where(valid, ops.pairwise_sqdist(c, x)[0], jnp.inf)
    return jnp.argmin(d).astype(jnp.int32)


EF_CEILING = 512  # §9.3: past this, O(ef²) beam maintenance dominates


def overfetch_ef(n: int, k: int, selectivity: float, ef: int) -> int:
    """The §9.3 low-selectivity over-fetch policy, in one place (serving
    and benchmarks must stay in sync with what DESIGN.md documents and
    fig12 validates): widen the beam toward ~4·k/selectivity so ~k
    allowed survivors exist, clamped at the corpus size and at the
    practical ceiling — beyond it the per-step `topr_merge` dedup
    (O(ef²) work and mask memory) costs more than the recall it buys,
    and traffic that needs more wants a pre-partitioned index."""
    return max(ef, min(n, math.ceil(4 * k / selectivity), EF_CEILING))


def default_visited_cap(ef: int) -> int:
    """Default hashed-table size: O(ef·expansion), independent of N.

    Each expansion inserts at most R fresh ids and the beam retires after
    ~ef expansions, so 8·ef slots keep the load factor low enough that
    capacity misses (harmless re-expansions) stay rare (DESIGN.md §6.1).
    """
    return max(256, 8 * ef)


def _table_insert(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Insert (Q, R) ids into the (Q, H) open-addressed tables.

    Sequential over the R slots (R is small), vectorized over queries, so
    no two inserts race for the same empty slot.  An id whose probe window
    holds neither itself nor an empty slot is dropped — a capacity miss,
    surfacing later as a harmless re-expansion.  ids < 0 are skipped.
    """
    q, h = table.shape
    r = ids.shape[1]
    qrows = jnp.arange(q, dtype=jnp.int32)

    def body(rr, tab):
        v = jax.lax.dynamic_index_in_dim(ids, rr, axis=1, keepdims=False)
        pos = visited_probe_positions(v, h)               # (Q, PL)
        vals = tab[qrows[:, None], pos]                   # (Q, PL)
        found = jnp.any(vals == v[:, None], axis=-1)
        empty = vals == -1
        has_empty = jnp.any(empty, axis=-1)
        ins = pos[qrows, jnp.argmax(empty, axis=-1)]      # first empty probe
        do = (v >= 0) & ~found & has_empty
        return tab.at[qrows, ins].set(jnp.where(do, v, tab[qrows, ins]))

    return jax.lax.fori_loop(0, r, body, table)


def _table_member(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Membership of (Q, R) ids in the (Q, H) open-addressed tables.

    Exactly the fused kernel's visited probe (ref.search_expand_ref /
    kernels/search_expand.py): the shared `visited_probe_positions` window,
    any-slot id match.  Hoisted for callers that must probe OUTSIDE the
    kernel — the corpus-sharded search (core/corpus_shard.py), where the
    kernel sees shard-LOCAL row indices but the visited set is keyed by
    GLOBAL ids — with bitwise-identical results by the kernel/oracle
    parity contract.  Callers mask ids < 0 themselves (as the kernel's
    `ok` mask does); this probe alone may report them either way.
    """
    q, h = table.shape
    pos = visited_probe_positions(ids, h)                 # (Q, R, PL)
    qrows = jnp.arange(q, dtype=jnp.int32)[:, None, None]
    return jnp.any(table[qrows, pos] == ids[..., None], axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "max_steps", "visited", "visited_cap",
                     "backend"))
def _search_impl(
    x,
    graph_ids: jnp.ndarray,
    queries: jnp.ndarray,
    entry: jnp.ndarray,
    valid: jnp.ndarray | None,
    rescore,
    vwords: jnp.ndarray | None,
    fwords: jnp.ndarray | None,
    ids_map: jnp.ndarray | None,
    *,
    k: int,
    ef: int,
    max_steps: int,
    visited: str,
    visited_cap: int,
    backend: str,
) -> SearchResult:
    # `backend` is unused in the body but part of the jit key: the kernels
    # dispatch on the global ops backend at TRACE time (same contract as
    # grnnd._build_graph_impl).
    del backend
    n, r = graph_ids.shape
    q = queries.shape[0]
    qrows = jnp.arange(q, dtype=jnp.int32)
    # trace-time flag, same idiom as the tombstone mask: the unfiltered
    # path compiles WITHOUT the predicate operands, the result heap, or
    # the extra per-step merge (tests/test_filtered.py jaxpr check)
    filtered = fwords is not None

    queries = queries.astype(jnp.float32)
    d_entry = ops.rowwise_sqdist(
        queries, jnp.broadcast_to(VS.take(x, entry), queries.shape))
    if valid is not None:
        # a dead entry contributes nothing; every later insertion into the
        # beam is already validity-filtered inside search_expand, so the
        # beam can never contain a tombstoned vertex
        d_entry = jnp.where(valid[entry], d_entry, jnp.inf)
    cand_ids = jnp.full((q, ef), -1, jnp.int32).at[:, 0].set(entry)
    cand_dists = jnp.full((q, ef), jnp.inf, jnp.float32).at[:, 0].set(d_entry)
    expanded = jnp.zeros((q, ef), bool)
    n_exp = jnp.zeros((q,), jnp.int32)

    if filtered:
        # result heap (route-through, DESIGN.md §9): the BEAM keeps every
        # live vertex so the walk can route through filtered-out regions;
        # only this separate heap — what the caller sees — applies the
        # predicate.  Seed it with the entry iff the entry itself passes.
        e_ok = jnp.any((vwords[entry][None, :] & fwords) != 0, axis=-1)
        e_ok = e_ok & jnp.isfinite(d_entry)
        res_ids = jnp.full((q, ef), -1, jnp.int32).at[:, 0].set(
            jnp.where(e_ok, entry, -1))
        res_dists = jnp.full((q, ef), jnp.inf, jnp.float32).at[:, 0].set(
            jnp.where(e_ok, d_entry, jnp.inf))

    entry_col = jnp.broadcast_to(entry, (q, 1)).astype(jnp.int32)
    if visited == "dense":
        vstate = jnp.zeros((q, n), bool).at[:, entry].set(True)
        # an empty 1-slot table turns the fused kernel's probe into a no-op
        lookup = jnp.full((q, 1), -1, jnp.int32)
    else:
        vstate = _table_insert(jnp.full((q, visited_cap), -1, jnp.int32),
                               entry_col)
        lookup = None

    def cond(state):
        frontier = (state[0] >= 0) & ~state[2]
        return (state[5] < max_steps) & jnp.any(frontier)

    def body(state):
        cand_ids, cand_dists, expanded, vstate, n_exp, steps = state[:6]
        frontier_d = jnp.where((cand_ids >= 0) & ~expanded, cand_dists, jnp.inf)
        sel = jnp.argmin(frontier_d, axis=-1)                      # (Q,)
        active = jnp.isfinite(jnp.min(frontier_d, axis=-1))        # (Q,)
        sel_id = cand_ids[qrows, sel]
        expanded = expanded.at[qrows, sel].set(True)

        nbrs = graph_ids[jnp.clip(sel_id, 0)]                      # (Q, R)
        nbrs = jnp.where(active[:, None] & (nbrs >= 0), nbrs, -1)

        # fused: gather neighbor vectors, query->neighbor distances, the
        # visited probe, the tombstone-validity probe, and (filtered) the
        # label-predicate test in one pass (dense mode probes the empty
        # dummy table and refines `fresh` with the exact bitmask below)
        out = ops.search_expand(
            x, queries, nbrs, vstate if lookup is None else lookup, valid,
            vwords if filtered else None, fwords if filtered else None)
        if filtered:
            nbrs, dq, fresh, allowed = out
        else:
            nbrs, dq, fresh = out
        if visited == "dense":
            seen = vstate[qrows[:, None], jnp.clip(nbrs, 0)]
            fresh = fresh & ~seen
            vstate = vstate.at[qrows[:, None], jnp.clip(nbrs, 0)].max(fresh)
        else:
            vstate = _table_insert(vstate, jnp.where(fresh, nbrs, -1))

        dq = jnp.where(fresh, dq, jnp.inf)
        n_exp = n_exp + jnp.sum(fresh, axis=-1, dtype=jnp.int32)

        # merge: keep ef best of (candidate list ∪ fresh neighbors) via the
        # deduplicating top-R primitive; candidates precede fresh entries,
        # so a re-entering duplicate keeps its original (possibly expanded)
        # beam slot.  Route-through: the beam takes fresh neighbors
        # REGARDLESS of the predicate — a filtered-out vertex must remain
        # a stepping stone to allowed ones beyond it.
        all_ids = jnp.concatenate([cand_ids, jnp.where(fresh, nbrs, -1)],
                                  axis=-1)
        all_d = jnp.concatenate([cand_dists, dq], axis=-1)
        new_ids, new_d = ops.topr_merge(all_ids, all_d, ef)

        # re-derive the expanded flags: an entry is expanded iff its id
        # matches a previously-expanded candidate slot (-2 sentinel keeps
        # empty slots from matching each other)
        exp_src = jnp.where(expanded & (cand_ids >= 0), cand_ids, -2)
        new_expanded = jnp.any(
            new_ids[:, :, None] == exp_src[:, None, :], axis=-1)
        new_expanded = new_expanded | (new_ids < 0)

        next_state = (new_ids, new_d, new_expanded, vstate, n_exp, steps + 1)
        if filtered:
            # a vertex enters the result heap exactly once — on its fresh
            # sighting, with its real distance, iff the predicate admits
            # it; re-sightings under hash-capacity misses are absorbed by
            # the merge dedup like everywhere else
            keep = fresh & allowed
            res_ids, res_dists = ops.topr_merge(
                jnp.concatenate([state[6], jnp.where(keep, nbrs, -1)],
                                axis=-1),
                jnp.concatenate([state[7], jnp.where(keep, dq, jnp.inf)],
                                axis=-1),
                ef)
            next_state = next_state + (res_ids, res_dists)
        return next_state

    state = (cand_ids, cand_dists, expanded, vstate, n_exp, jnp.int32(0))
    if filtered:
        state = state + (res_ids, res_dists)
    state = jax.lax.while_loop(cond, body, state)
    cand_ids, cand_dists, n_exp = state[0], state[1], state[4]
    out_ids, out_dists = ((state[6], state[7]) if filtered
                          else (cand_ids, cand_dists))

    if rescore is not None:
        # fp32 rescoring pass (DESIGN.md §8.3): traversal ranked the beam
        # in the storage precision's distance space; re-rank the final ef
        # candidates with EXACT distances against the rescore tier.  One
        # (Q, ef, D) gather — ef·D bytes per query, tiny next to the
        # traversal traffic — then the usual dedup/sort merge primitive
        # (ids are already unique, so this is a pure re-sort).  Under a
        # filter this runs on the result heap, which holds ONLY allowed
        # ids — rescoring is restricted to the allowed set by construction.
        rv = VS.take(rescore, jnp.clip(out_ids, 0))            # (Q, ef, D)
        diff = queries[:, None, :] - rv
        d_exact = jnp.sum(diff * diff, axis=-1)
        d_exact = jnp.where(out_ids >= 0, d_exact, jnp.inf)
        out_ids, out_dists = ops.topr_merge(out_ids, d_exact, ef)

    out_ids, out_dists = out_ids[:, :k], out_dists[:, :k]
    if ids_map is not None:
        # optimized layout (core/layout.py): the graph rows are permuted;
        # one final gather converts internal row indices back to the
        # caller's original numbering.  Runs AFTER the k-slice and the
        # rescore re-rank, so everything upstream is untouched.
        out_ids = jnp.where(out_ids >= 0, ids_map[jnp.clip(out_ids, 0)], -1)
    return SearchResult(out_ids, out_dists, n_exp)


@functools.partial(jax.jit, static_argnames=("k",))
def _rescore_merge(out_ids, rv, queries, ids_map, *, k: int):
    """The re-rank half of the host-tier search (DESIGN.md §13).

    Identical math, line for line, to the in-loop rescore tail of
    `_search_impl`: exact fp32 distances against the gathered rows, pad
    slots masked to +inf BY ID (so the gathered content of a pad row is
    irrelevant — the host gather ships zeros for them), the same
    `topr_merge` re-sort, the same k-slice-then-ids_map order.  Running
    it as a second jitted program instead of inside the traversal
    program cannot change a bit: every op is the same jnp formula on the
    same operands (the corpus-shard tier relies on the identical
    same-formula-across-programs contract).
    """
    ef = out_ids.shape[1]
    diff = queries[:, None, :] - rv
    d_exact = jnp.sum(diff * diff, axis=-1)
    d_exact = jnp.where(out_ids >= 0, d_exact, jnp.inf)
    out_ids, out_dists = ops.topr_merge(out_ids, d_exact, ef)
    out_ids, out_dists = out_ids[:, :k], out_dists[:, :k]
    if ids_map is not None:
        out_ids = jnp.where(out_ids >= 0, ids_map[jnp.clip(out_ids, 0)], -1)
    return out_ids, out_dists


def search(
    x,
    graph_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int = 10,
    ef: int = 64,
    max_steps: int = 512,
    entry: jnp.ndarray | None = None,
    visited: str = "dense",
    visited_cap: int | None = None,
    valid: jnp.ndarray | None = None,
    rescore=None,
    labels=None,
    filter=None,
    overfetch: int = 4,
    ids_map: jnp.ndarray | None = None,
) -> SearchResult:
    """Search the graph for the k nearest vertices to each query row.

    `x` is the traversal-tier dataset: a plain fp32 array or a
    `core.vecstore.VectorStore` (bf16 / int8 per the precision ladder,
    DESIGN.md §8) — the fused expansion kernel dequantizes rows on the fly.

    `visited` selects the visited-set representation: "dense" (exact (Q, N)
    bitmask) or "hashed" (per-query `visited_cap`-slot open-addressed table,
    O(Q·H) memory independent of N — the serving configuration at scale).
    `visited_cap` defaults to `default_visited_cap(ef)`.

    `valid` is the dynamic index's (N,) vertex-validity mask (tombstoned or
    not-yet-allocated rows are False, core/dynamic.py): dead vertices are
    excluded from traversal entirely — never expanded, scored, or returned
    — so the result set is exactly what a search over the physically
    compacted graph would produce.  None (the static-index default) keeps
    the original path bit-for-bit.

    `rescore` is the optional exact tier for quantized traversal (the
    CAGRA/GGNN two-tier layout): an (N, D) fp32 array (or higher-precision
    store) from which the final ef candidates are re-ranked with exact
    distances.  None (the default) returns traversal-space distances
    unchanged — the fp32 path stays bit-for-bit.  A `vecstore.HostTier`
    selects the HOST-COLD placement (DESIGN.md §13): traversal runs
    device-side without the rescore operand, the final ef candidate ids
    cross to the host, ef·D fp32 bytes come back (pad slots excluded from
    the transfer), and `_rescore_merge` re-ranks with the identical math
    — bitwise-equal to the device-resident tier (tests/test_tiered.py).

    `labels`/`filter` select FILTERED search (core/labels.py, DESIGN.md
    §9): `labels` is a `LabelStore` (or raw (N, W) packed vertex words)
    and `filter` the per-query predicate — (Q, W) packed allowed words, a
    (Q, L) boolean label mask, or (Q,) single allowed label ids.  The
    traversal ROUTES THROUGH filtered-out vertices (they stay in the beam
    with their real distances, preserving graph connectivity under
    masking) while a separate result heap admits only predicate-passing
    vertices — every returned id satisfies its query's predicate, a hard
    invariant.  `overfetch` widens the working ef to at least
    `overfetch * k` under a filter so k allowed survivors remain at
    moderate selectivity; at LOW selectivity callers should additionally
    raise `ef` toward ~k/selectivity (the over-fetch policy, DESIGN.md
    §9.3).  None (the default) keeps the unfiltered path bit-for-bit —
    the predicate operands are absent from the compiled program entirely.

    `ids_map` is the optimized-layout inverse permutation (core/layout.py):
    an (N,) int32 map applied to the returned ids in one final gather, so
    an index whose rows were renumbered for locality still reports ids in
    the caller's original numbering.  None (the default) keeps the
    unmapped path bit-for-bit (the gather is absent from the trace).
    """
    assert ef >= k
    assert visited in ("dense", "hashed"), visited
    assert visited_cap is None or visited_cap > 0, visited_cap
    if filter is not None:
        assert labels is not None, "filtered search needs a label store"
        vwords = L.store_words(labels)
        fwords = L.query_words(filter, vwords.shape[1])
        ef = max(ef, overfetch * k)
    else:
        vwords = fwords = None  # labels alone is inert (no predicate given)
    if entry is None:
        entry = medoid(x, valid)
    if visited == "dense":
        cap = 0  # unused; normalized so it never fragments the jit cache
    else:
        cap = visited_cap if visited_cap is not None else default_visited_cap(ef)
    if VS.is_host(rescore):
        # host-cold tier: traversal compiles WITHOUT the rescore operand
        # (k=ef keeps the full beam/heap — the k-slice is deferred to the
        # merge program), the gather crosses the boundary in host numpy,
        # and the re-rank runs as its own jitted program.  ids_map is
        # also deferred so the host gather indexes internal row numbers.
        res = _search_impl(x, graph_ids, queries, entry, valid, None,
                           vwords, fwords, None,
                           k=ef, ef=ef, max_steps=max_steps,
                           visited=visited, visited_cap=cap,
                           backend=ops.effective_backend())
        rv = rescore.gather(res.ids)                       # (Q, ef, D)
        out_ids, out_dists = _rescore_merge(
            res.ids, rv, jnp.asarray(queries, jnp.float32), ids_map, k=k)
        return SearchResult(out_ids, out_dists, res.n_expanded)
    return _search_impl(x, graph_ids, queries, entry, valid, rescore,
                        vwords, fwords, ids_map,
                        k=k, ef=ef, max_steps=max_steps,
                        visited=visited, visited_cap=cap,
                        backend=ops.effective_backend())
