"""Batched best-first graph search over a GRNND/RNN-Descent graph.

Standard greedy beam search (the "fixed search algorithm" the paper uses to
compare indices): a candidate list of size `ef` per query, expand the closest
unexpanded candidate, push its unvisited neighbors, stop when every list
entry is expanded.  Fully batched over queries with jax.lax.while_loop.

The production pieces (DESIGN.md §6):

  * the expansion step — gather the selected vertex's R neighbor vectors,
    compute query->neighbor distances, probe the visited set — is one fused
    op (`ops.search_expand`, kernels/search_expand.py) with a ref.py oracle;
  * the visited set is selectable: `visited="dense"` keeps the exact (Q, N)
    bitmask (right at reproduction scale), `visited="hashed"` replaces it
    with a fixed-size per-query open-addressed table of `visited_cap` int32
    slots, making search memory O(Q·H) independent of N.  Collisions and
    capacity misses only cause harmless re-expansions, never false skips;
    with `visited_cap >= N` the hashed path is provably collision-free and
    bitwise-identical to the dense reference (tests/test_search_parity.py);
  * the per-step beam merge is the deduplicating `ops.topr_merge` primitive
    the build path already uses — no full (Q, ef+R) argsort per step, and
    re-entering duplicates (possible under hash capacity misses) are
    absorbed instead of crowding the beam.

Query sharding over a device mesh lives in `core.distributed.
distributed_search` (x and graph replicated, queries sharded — searches are
embarrassingly parallel over queries).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import vecstore as VS
from repro.kernels import ops
from repro.kernels.ref import visited_probe_positions


class SearchResult(NamedTuple):
    ids: jnp.ndarray     # (Q, k) int32
    dists: jnp.ndarray   # (Q, k) float32
    n_expanded: jnp.ndarray  # (Q,) int32 — distance computations proxy


def medoid(x, valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Entry point: vertex nearest to the dataset centroid.

    With a `valid` mask (dynamic index: tombstones + unallocated padded
    rows, core/dynamic.py), both the centroid and the argmin are restricted
    to live rows, so the entry is always a live vertex.  `x` may be a
    VectorStore: the centroid is taken over the dequantized corpus (a
    one-shot startup computation, not a hot path) so the entry choice
    matches what the traversal distances will see.
    """
    if valid is None:
        c = jnp.mean(VS.dequant(x), axis=0, keepdims=True)
        return jnp.argmin(ops.pairwise_sqdist(c, x)[0]).astype(jnp.int32)
    v = valid.astype(jnp.float32)
    c = (jnp.sum(VS.dequant(x) * v[:, None], axis=0)
         / jnp.maximum(jnp.sum(v), 1.0))[None, :]
    d = jnp.where(valid, ops.pairwise_sqdist(c, x)[0], jnp.inf)
    return jnp.argmin(d).astype(jnp.int32)


def default_visited_cap(ef: int) -> int:
    """Default hashed-table size: O(ef·expansion), independent of N.

    Each expansion inserts at most R fresh ids and the beam retires after
    ~ef expansions, so 8·ef slots keep the load factor low enough that
    capacity misses (harmless re-expansions) stay rare (DESIGN.md §6.1).
    """
    return max(256, 8 * ef)


def _table_insert(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Insert (Q, R) ids into the (Q, H) open-addressed tables.

    Sequential over the R slots (R is small), vectorized over queries, so
    no two inserts race for the same empty slot.  An id whose probe window
    holds neither itself nor an empty slot is dropped — a capacity miss,
    surfacing later as a harmless re-expansion.  ids < 0 are skipped.
    """
    q, h = table.shape
    r = ids.shape[1]
    qrows = jnp.arange(q, dtype=jnp.int32)

    def body(rr, tab):
        v = jax.lax.dynamic_index_in_dim(ids, rr, axis=1, keepdims=False)
        pos = visited_probe_positions(v, h)               # (Q, PL)
        vals = tab[qrows[:, None], pos]                   # (Q, PL)
        found = jnp.any(vals == v[:, None], axis=-1)
        empty = vals == -1
        has_empty = jnp.any(empty, axis=-1)
        ins = pos[qrows, jnp.argmax(empty, axis=-1)]      # first empty probe
        do = (v >= 0) & ~found & has_empty
        return tab.at[qrows, ins].set(jnp.where(do, v, tab[qrows, ins]))

    return jax.lax.fori_loop(0, r, body, table)


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "max_steps", "visited", "visited_cap",
                     "backend"))
def _search_impl(
    x,
    graph_ids: jnp.ndarray,
    queries: jnp.ndarray,
    entry: jnp.ndarray,
    valid: jnp.ndarray | None,
    rescore,
    *,
    k: int,
    ef: int,
    max_steps: int,
    visited: str,
    visited_cap: int,
    backend: str,
) -> SearchResult:
    # `backend` is unused in the body but part of the jit key: the kernels
    # dispatch on the global ops backend at TRACE time (same contract as
    # grnnd._build_graph_impl).
    del backend
    n, r = graph_ids.shape
    q = queries.shape[0]
    qrows = jnp.arange(q, dtype=jnp.int32)

    queries = queries.astype(jnp.float32)
    d_entry = ops.rowwise_sqdist(
        queries, jnp.broadcast_to(VS.take(x, entry), queries.shape))
    if valid is not None:
        # a dead entry contributes nothing; every later insertion into the
        # beam is already validity-filtered inside search_expand, so the
        # beam can never contain a tombstoned vertex
        d_entry = jnp.where(valid[entry], d_entry, jnp.inf)
    cand_ids = jnp.full((q, ef), -1, jnp.int32).at[:, 0].set(entry)
    cand_dists = jnp.full((q, ef), jnp.inf, jnp.float32).at[:, 0].set(d_entry)
    expanded = jnp.zeros((q, ef), bool)
    n_exp = jnp.zeros((q,), jnp.int32)

    entry_col = jnp.broadcast_to(entry, (q, 1)).astype(jnp.int32)
    if visited == "dense":
        vstate = jnp.zeros((q, n), bool).at[:, entry].set(True)
        # an empty 1-slot table turns the fused kernel's probe into a no-op
        lookup = jnp.full((q, 1), -1, jnp.int32)
    else:
        vstate = _table_insert(jnp.full((q, visited_cap), -1, jnp.int32),
                               entry_col)
        lookup = None

    def cond(state):
        cand_ids, cand_dists, expanded, vstate, n_exp, steps = state
        frontier = (cand_ids >= 0) & ~expanded
        return (steps < max_steps) & jnp.any(frontier)

    def body(state):
        cand_ids, cand_dists, expanded, vstate, n_exp, steps = state
        frontier_d = jnp.where((cand_ids >= 0) & ~expanded, cand_dists, jnp.inf)
        sel = jnp.argmin(frontier_d, axis=-1)                      # (Q,)
        active = jnp.isfinite(jnp.min(frontier_d, axis=-1))        # (Q,)
        sel_id = cand_ids[qrows, sel]
        expanded = expanded.at[qrows, sel].set(True)

        nbrs = graph_ids[jnp.clip(sel_id, 0)]                      # (Q, R)
        nbrs = jnp.where(active[:, None] & (nbrs >= 0), nbrs, -1)

        # fused: gather neighbor vectors, query->neighbor distances, the
        # visited probe, and the tombstone-validity probe in one pass (dense
        # mode probes the empty dummy table and refines `fresh` with the
        # exact bitmask below)
        nbrs, dq, fresh = ops.search_expand(
            x, queries, nbrs, vstate if lookup is None else lookup, valid)
        if visited == "dense":
            seen = vstate[qrows[:, None], jnp.clip(nbrs, 0)]
            fresh = fresh & ~seen
            vstate = vstate.at[qrows[:, None], jnp.clip(nbrs, 0)].max(fresh)
        else:
            vstate = _table_insert(vstate, jnp.where(fresh, nbrs, -1))

        dq = jnp.where(fresh, dq, jnp.inf)
        n_exp = n_exp + jnp.sum(fresh, axis=-1, dtype=jnp.int32)

        # merge: keep ef best of (candidate list ∪ fresh neighbors) via the
        # deduplicating top-R primitive; candidates precede fresh entries,
        # so a re-entering duplicate keeps its original (possibly expanded)
        # beam slot
        all_ids = jnp.concatenate([cand_ids, jnp.where(fresh, nbrs, -1)],
                                  axis=-1)
        all_d = jnp.concatenate([cand_dists, dq], axis=-1)
        new_ids, new_d = ops.topr_merge(all_ids, all_d, ef)

        # re-derive the expanded flags: an entry is expanded iff its id
        # matches a previously-expanded candidate slot (-2 sentinel keeps
        # empty slots from matching each other)
        exp_src = jnp.where(expanded & (cand_ids >= 0), cand_ids, -2)
        new_expanded = jnp.any(
            new_ids[:, :, None] == exp_src[:, None, :], axis=-1)
        new_expanded = new_expanded | (new_ids < 0)

        return new_ids, new_d, new_expanded, vstate, n_exp, steps + 1

    state = (cand_ids, cand_dists, expanded, vstate, n_exp, jnp.int32(0))
    cand_ids, cand_dists, expanded, vstate, n_exp, _ = jax.lax.while_loop(
        cond, body, state)

    if rescore is not None:
        # fp32 rescoring pass (DESIGN.md §8.3): traversal ranked the beam
        # in the storage precision's distance space; re-rank the final ef
        # candidates with EXACT distances against the rescore tier.  One
        # (Q, ef, D) gather — ef·D bytes per query, tiny next to the
        # traversal traffic — then the usual dedup/sort merge primitive
        # (ids are already unique, so this is a pure re-sort).
        rv = VS.take(rescore, jnp.clip(cand_ids, 0))           # (Q, ef, D)
        diff = queries[:, None, :] - rv
        d_exact = jnp.sum(diff * diff, axis=-1)
        d_exact = jnp.where(cand_ids >= 0, d_exact, jnp.inf)
        cand_ids, cand_dists = ops.topr_merge(cand_ids, d_exact, ef)

    return SearchResult(cand_ids[:, :k], cand_dists[:, :k], n_exp)


def search(
    x,
    graph_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int = 10,
    ef: int = 64,
    max_steps: int = 512,
    entry: jnp.ndarray | None = None,
    visited: str = "dense",
    visited_cap: int | None = None,
    valid: jnp.ndarray | None = None,
    rescore=None,
) -> SearchResult:
    """Search the graph for the k nearest vertices to each query row.

    `x` is the traversal-tier dataset: a plain fp32 array or a
    `core.vecstore.VectorStore` (bf16 / int8 per the precision ladder,
    DESIGN.md §8) — the fused expansion kernel dequantizes rows on the fly.

    `visited` selects the visited-set representation: "dense" (exact (Q, N)
    bitmask) or "hashed" (per-query `visited_cap`-slot open-addressed table,
    O(Q·H) memory independent of N — the serving configuration at scale).
    `visited_cap` defaults to `default_visited_cap(ef)`.

    `valid` is the dynamic index's (N,) vertex-validity mask (tombstoned or
    not-yet-allocated rows are False, core/dynamic.py): dead vertices are
    excluded from traversal entirely — never expanded, scored, or returned
    — so the result set is exactly what a search over the physically
    compacted graph would produce.  None (the static-index default) keeps
    the original path bit-for-bit.

    `rescore` is the optional exact tier for quantized traversal (the
    CAGRA/GGNN two-tier layout): an (N, D) fp32 array (or higher-precision
    store) from which the final ef candidates are re-ranked with exact
    distances.  None (the default) returns traversal-space distances
    unchanged — the fp32 path stays bit-for-bit.
    """
    assert ef >= k
    assert visited in ("dense", "hashed"), visited
    assert visited_cap is None or visited_cap > 0, visited_cap
    if entry is None:
        entry = medoid(x, valid)
    if visited == "dense":
        cap = 0  # unused; normalized so it never fragments the jit cache
    else:
        cap = visited_cap if visited_cap is not None else default_visited_cap(ef)
    return _search_impl(x, graph_ids, queries, entry, valid, rescore,
                        k=k, ef=ef, max_steps=max_steps,
                        visited=visited, visited_cap=cap,
                        backend=ops.effective_backend())
