"""VectorStore: the precision ladder for dataset vectors (DESIGN.md §8).

Every distance in the repo — build (`pairwise_l2`, `rng_round`), query
(`search_expand`, `gather_l2`), and the dynamic path — reads rows of the
(N, D) dataset.  At fp32 that is 4·D bytes per row of HBM/VMEM traffic on
paths that are memory-bound (EXPERIMENTS.md §Perf), so storage precision
directly caps build N and serve QPS.  `VectorStore` holds the vectors at
one of three rungs:

  * ``fp32`` — the exact baseline (a plain array wrapped unchanged);
  * ``bf16`` — 2 bytes/dim; kernels widen to fp32 on load, so distances
    differ from fp32 only by the storage rounding of the inputs;
  * ``int8`` — 1 byte/dim scalar quantization with per-dimension affine
    (scale, offset) computed from the corpus at build/encode time:

        q = clip(round((x - offset) / scale), -127, 127)     stored int8
        x̂ = q · scale + offset                               dequant

    The dequant is FUSED into the kernels (each DMA'd row is widened and
    affine-corrected in VMEM); the (N, D) fp32 dequantized matrix never
    exists.  Distances always accumulate in fp32 on the MXU.

The dequant ``x̂ = q·scale + offset`` is elementwise, so computing it
inside a kernel and inside the ref.py oracle produces bitwise-identical
fp32 rows — the precision ladder preserves the kernel/oracle bitwise
parity contract (tests/test_precision.py).

The int8 rung is approximate; exact results come back via the fp32
RESCORING pass after beam search (core/search.py `rescore=`): the top-ef
candidate ids gather their fp32 rows (ef·D bytes per query — tiny next to
traversal traffic) and are re-ranked with exact distances, the
CAGRA/GGNN two-tier layout.

TIER PLACEMENT (DESIGN.md §13): the rescore tier touches only the final
ef candidate rows per query, so it does not have to live in device
memory at all.  `HostTier` pins the dequantized fp32 tier on the host
(CPU) backend and serves the rescore gather across the boundary — the
traversal tier (this store) stays device-resident, and device memory
holds int8 + graph only.  `PLACEMENTS` names the axis; `is_host` is the
placement probe every rescore consumer branches on.

This module depends only on jax and `kernels/ref.py` (the shared dequant
formula); kernels/ops.py duck-types on the (data, scale, offset) triple,
so no import cycle with the core package exists.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# the single dequant formula, shared with the kernel oracles (and inlined,
# operation-for-operation, in the Pallas kernel bodies)
from repro.kernels.ref import dequant_rows

PRECISIONS = ("fp32", "bf16", "int8")

# int8 quantization range: symmetric ±127 around the per-dim midpoint
# (255 levels would make round-trip error asymmetric at the range edges)
_QLEVELS = 254.0


class VectorStore(NamedTuple):
    """Dataset vectors at one rung of the precision ladder.

    data   (N, D) float32 | bfloat16 | int8
    scale  (D,)   float32 — per-dim dequant scale; None for float rungs
    offset (D,)   float32 — per-dim dequant offset; None for float rungs

    A NamedTuple so it is a jit-able pytree; the None scale/offset of the
    float rungs are part of the treedef, giving the kernels a trace-time
    `quantized` flag exactly like the search path's `valid=None` contract.
    """
    data: jnp.ndarray
    scale: jnp.ndarray | None = None
    offset: jnp.ndarray | None = None

    @property
    def precision(self) -> str:
        if self.data.dtype == jnp.int8:
            return "int8"
        if self.data.dtype == jnp.bfloat16:
            return "bf16"
        return "fp32"

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[1]

    @property
    def shape(self) -> tuple[int, ...]:
        """Logical (N, D) — lets store-aware callers keep array idiom."""
        return self.data.shape

    def bytes_per_vector(self, include_overhead: bool = False) -> float:
        """Storage bytes per row; overhead = the shared (D,) scale/offset
        amortized over N (negligible at any real N — reported separately
        so the ≥2x/≥4x reduction claims stay clean)."""
        per_row = self.dim * self.data.dtype.itemsize
        if include_overhead and self.scale is not None:
            per_row += 8.0 * self.dim / max(self.n, 1)
        return float(per_row)

    def dequant(self) -> jnp.ndarray:
        """Full (N, D) fp32 view (entry-point selection / one-shot uses;
        hot paths must go through the fused kernel operands instead)."""
        return dequant_rows(self.data, self.scale, self.offset)

    def take(self, idx: jnp.ndarray) -> jnp.ndarray:
        """Gather rows by index -> fp32, dequantized (any idx shape)."""
        return dequant_rows(self.data[idx], self.scale, self.offset)

    def quantize_rows(self, x: jnp.ndarray) -> jnp.ndarray:
        """Encode new fp32 rows with this store's FROZEN parameters (the
        dynamic-index insert path).  Values outside the build-time range
        clip to the range edge."""
        x = jnp.asarray(x)
        if self.scale is None:
            return x.astype(self.data.dtype)
        q = jnp.round((x.astype(jnp.float32) - self.offset) / self.scale)
        return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)

    def requant(self, x: jnp.ndarray) -> jnp.ndarray:
        """Round-trip fp32 rows through this store's representation: the
        value the kernels would see if the rows were stored.  Keeps
        off-store distance math (e.g. the dynamic bootstrap) in the same
        distance space as the graph."""
        return dequant_rows(self.quantize_rows(x), self.scale, self.offset)

    def with_rows(self, idx: jnp.ndarray, x: jnp.ndarray) -> "VectorStore":
        """Functionally set rows `idx` to (encoded) fp32 rows `x`."""
        return self._replace(data=self.data.at[idx].set(self.quantize_rows(x)))


def quantize_int8(x: jnp.ndarray) -> VectorStore:
    """Per-dimension affine int8 quantization of an (N, D) fp32 corpus.

    scale/offset are chosen from the per-dim [min, max] so the whole
    corpus is in-range: round-trip error obeys |x - x̂| <= scale/2
    elementwise (tests/test_precision.py property tier).  A constant
    dimension gets scale 1 (q = 0 everywhere, x̂ = offset = the constant,
    zero error) rather than a 0/0.

    An EMPTY (0, D) corpus is well-defined: scale 1, offset 0 per dim
    (the constant-dimension convention with nothing observed), so the
    empty-then-grow dynamic-index path can encode before any insert —
    `jnp.min` over the empty axis has no identity and would raise.
    """
    x = jnp.asarray(x, jnp.float32)
    if x.shape[0] == 0:
        d = x.shape[1]
        return VectorStore(jnp.zeros((0, d), jnp.int8),
                           jnp.ones((d,), jnp.float32),
                           jnp.zeros((d,), jnp.float32))
    lo = jnp.min(x, axis=0)
    hi = jnp.max(x, axis=0)
    offset = lo + (hi - lo) * 0.5
    scale = jnp.where(hi > lo, (hi - lo) / _QLEVELS, 1.0)
    q = jnp.clip(jnp.round((x - offset) / scale), -127.0, 127.0)
    return VectorStore(q.astype(jnp.int8), scale, offset)


def encode(x: jnp.ndarray, precision: str) -> VectorStore:
    """Encode an (N, D) corpus at the given precision rung."""
    assert precision in PRECISIONS, \
        f"precision must be one of {PRECISIONS}, got {precision!r}"
    if precision == "int8":
        return quantize_int8(x)
    if precision == "bf16":
        return VectorStore(jnp.asarray(x).astype(jnp.bfloat16))
    return VectorStore(jnp.asarray(x, jnp.float32))


# -- store-or-array helpers (the build/search layers accept either) --------

def as_store(x) -> VectorStore:
    return x if isinstance(x, VectorStore) else VectorStore(jnp.asarray(x))


def parts(x) -> tuple[jnp.ndarray, jnp.ndarray | None, jnp.ndarray | None]:
    """(data, scale, offset) of a store, or (x, None, None) for an array."""
    if isinstance(x, VectorStore):
        return x.data, x.scale, x.offset
    return x, None, None


def nrows(x) -> int:
    return x.shape[0]


def dim(x) -> int:
    return x.shape[1]


def take(x, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather rows -> fp32 (dequantized for stores, widened for arrays)."""
    if isinstance(x, VectorStore):
        return x.take(idx)
    return x[idx].astype(jnp.float32)


def dequant(x) -> jnp.ndarray:
    """(N, D) fp32 view of a store or array."""
    if isinstance(x, VectorStore):
        return x.dequant()
    return jnp.asarray(x).astype(jnp.float32)


def precision_of(x) -> str:
    return as_store(x).precision


# -- tier placement: device-hot traversal, host-cold rescore (§13) ----------

PLACEMENTS = ("device", "host")


def host_device():
    """The host-side placement target: the first CPU backend device."""
    return jax.devices("cpu")[0]


class HostTier:
    """The fp32 rescore tier, pinned host-side (DESIGN.md §13).

    Wraps the PRE-DEQUANTIZED (N, D) fp32 matrix committed to the CPU
    backend (`jax.device_put`).  Pre-dequantizing follows the
    corpus-shard precedent (`CorpusShardedIndex.rescores`): the rows a
    gather returns are produced by the one shared `dequant_rows`
    formula, so they are bitwise-identical to what `VectorStore.take`
    yields on-device, and the re-rank math downstream cannot diverge.

    Deliberately a PLAIN CLASS, not a NamedTuple/pytree: it can never be
    passed into a jitted program by accident.  The gather happens in
    host numpy between the two jitted halves of the search (traversal,
    then `_rescore_merge`), which is exactly the explicit host/device
    boundary the tier exists to create.

    Pad slots (`id == -1`) are masked OUT of the transfer — their row
    content is irrelevant because the merge masks their distance to +inf
    — and `fetched_rows` counts only real rows, making the cross-
    boundary traffic (ef·D·4 bytes per query, minus pads) observable.
    """

    def __init__(self, x):
        self.data = jax.device_put(dequant(x), host_device())
        # zero-copy on CPU backends; one D2H copy otherwise, at init only
        self._np = np.asarray(self.data)
        self.fetched_rows = 0

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[1]

    @property
    def shape(self) -> tuple[int, ...]:
        return self.data.shape

    def device_bytes(self) -> int:
        """Accelerator-resident bytes of this tier: none, by contract."""
        return 0

    def host_bytes(self) -> int:
        return int(self._np.nbytes)

    def gather(self, ids) -> jnp.ndarray:
        """Fetch fp32 rows for candidate ids (any shape); pad slots
        (`-1`) transfer nothing and come back as zero rows (the merge
        never reads them — it masks by id, not by content)."""
        ids_np = np.asarray(ids)
        sel = ids_np >= 0
        out = np.zeros(ids_np.shape + (self._np.shape[1],), np.float32)
        out[sel] = self._np[ids_np[sel]]
        self.fetched_rows += int(sel.sum())
        return jnp.asarray(out)


def is_host(x) -> bool:
    """Placement probe: is this rescore operand the host-cold tier?"""
    return isinstance(x, HostTier)
