"""Data pipeline: deterministic, shardable, restart-safe synthetic streams.

Batches are generated per (step, host) from counter-based PRNG keys, so:
  * any host can regenerate any step's shard (restart-safe without data
    checkpointing),
  * straggler-skipped shards are reproducible for audits,
  * the global batch is identical for any mesh layout (elastic-safe).
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import synthetic


def batch_for_step(cfg: ArchConfig, step: int, batch: int, seq: int,
                   seed: int = 0) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    if cfg.modality == "audio_tokens":
        return {"tokens": jax.random.randint(
            key, (batch, seq, cfg.n_codebooks), 0, cfg.vocab, jnp.int32)}
    if cfg.modality == "vision_text":
        k1, k2 = jax.random.split(key)
        return {
            "tokens": synthetic.token_stream(
                k1, batch, seq - cfg.vision_tokens, cfg.vocab),
            "patch_embeds": 0.1 * jax.random.normal(
                k2, (batch, cfg.vision_tokens, cfg.vision_dim)),
        }
    return {"tokens": synthetic.token_stream(key, batch, seq, cfg.vocab)}


def stream(cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
           start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_for_step(cfg, step, batch, seq, seed)
        step += 1
