"""Synthetic datasets: vector corpora (ANN benchmarks) + LM token streams.

Vector datasets model the paper's benchmark families at reduced scale:
  * "sift-like"  — clustered, moderate dimension (SIFT1M: D=128)
  * "deep-like"  — unit-norm embeddings (DEEP1M: D=96)
  * "gist-like"  — high dimension (GIST1M: D=960)

Clustered Gaussian mixtures reproduce the local-neighborhood structure that
makes graph ANN interesting (uniform data has no cluster structure and makes
every method look alike).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vector_dataset(
    key: jax.Array,
    n: int,
    d: int,
    n_clusters: int = 64,
    cluster_std: float = 0.15,
    normalize: bool = False,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Clustered Gaussian mixture, roughly unit-scale coordinates."""
    kc, ka, kn = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_clusters, d), jnp.float32)
    assign = jax.random.randint(ka, (n,), 0, n_clusters)
    pts = centers[assign] + cluster_std * jax.random.normal(kn, (n, d), jnp.float32)
    if normalize:
        pts = pts / jnp.linalg.norm(pts, axis=-1, keepdims=True)
    return pts.astype(dtype)


def queries_from(key: jax.Array, x: jnp.ndarray, q: int, noise: float = 0.05):
    """Queries near dataset points (the realistic ANN query regime)."""
    ki, kn = jax.random.split(key)
    idx = jax.random.randint(ki, (q,), 0, x.shape[0])
    return x[idx] + noise * jax.random.normal(kn, (q, x.shape[1]), x.dtype)


DATASET_PRESETS = {
    # name: (d, n_clusters, normalize)  — reduced-scale stand-ins
    "sift-like": (128, 128, False),
    "deep-like": (96, 128, True),
    "gist-like": (960, 64, False),
    "tiny": (16, 16, False),
}


def make_preset(key: jax.Array, name: str, n: int) -> jnp.ndarray:
    d, ncl, norm = DATASET_PRESETS[name]
    return vector_dataset(key, n, d, n_clusters=ncl, normalize=norm)


def token_stream(key: jax.Array, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Zipf-ish synthetic token ids for LM training."""
    u = jax.random.uniform(key, (batch, seq), jnp.float32, 1e-6, 1.0)
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(vocab)))) - 1.0
    return jnp.clip(ranks, 0, vocab - 1).astype(jnp.int32)
