"""Gradient compression for cross-pod (DCN) reductions.

int8 block-quantized all-reduce with error feedback: the pod axis crosses
data-center network, where 4x compression matters; ICI reductions inside a
pod stay full precision.  Error feedback (persistent residual) keeps the
quantization noise from biasing convergence — see tests for the convergence
property.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, block: int = 256):
    """Symmetric per-block int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, block: int = 256):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compressed_psum_mean(x: jnp.ndarray, axis: str, block: int = 256):
    """Mean-reduce over `axis` with int8 payload (inside shard_map).

    Two-phase: (1) pmax of per-block maxima establishes a SHARED scale
    (payload = 1/block of the tensor, fp32); (2) every shard quantizes
    against the shared scale, int8 payloads sum exactly in int32, and one
    dequantize recovers the mean.  Error is bounded by the quantization
    step — no cross-shard scale mismatch term.
    """
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)

    local_max = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    shared = jax.lax.pmax(local_max, axis)                 # phase 1 (tiny)
    scale = jnp.maximum(shared / 127.0, 1e-12)

    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    n = jax.lax.psum(1, axis)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)        # phase 2 (int8-ish)
    out = (q_sum.astype(jnp.float32) * scale).reshape(-1)
    m = 1
    for d in x.shape:
        m *= d
    return (out[:m].reshape(x.shape) / n).astype(x.dtype)


class ErrorFeedback:
    """Residual-carrying compressor: g_hat = C(g + e);  e += (g - g_hat)."""

    @staticmethod
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    @staticmethod
    def compress(grads, residual, block: int = 256):
        def one(g, e):
            x = g.astype(jnp.float32) + e
            q, s = quantize_int8(x, block)
            deq = dequantize_int8(q, s, x.shape, block)
            return deq.astype(g.dtype), x - deq
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(residual)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in outs]),
                treedef.unflatten([o[1] for o in outs]))
