"""Fault tolerance + elasticity + straggler mitigation.

On real pods these hooks sit around the JAX distributed runtime
(jax.distributed.initialize + coordinator).  The control-plane logic —
heartbeats, failure detection, elastic re-meshing, deadline-based straggler
skipping — is hardware-independent and implemented (and tested) here against
a simulated host set.  The data plane (checkpoint restore + resharding) is
the real implementation in checkpoint/checkpoint.py.

Recovery contract (exercised by tests/test_fault_tolerance.py):
  1. trainer checkpoints every K steps (atomic commit);
  2. coordinator detects a missed heartbeat, removes the host, and picks
     the largest feasible mesh from the survivors (elastic re-mesh);
  3. restart restores the latest committed step with the new mesh's
     shardings — training continues bit-exact from the checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    alive: bool = True


class Coordinator:
    """Failure detection + elastic mesh sizing over a (simulated) host set."""

    def __init__(self, n_hosts: int, heartbeat_timeout: float = 10.0,
                 now: Callable[[], float] = time.monotonic):
        self._now = now
        self.timeout = heartbeat_timeout
        t = now()
        self.hosts = {i: HostState(i, t) for i in range(n_hosts)}

    def heartbeat(self, host_id: int) -> None:
        h = self.hosts[host_id]
        h.last_heartbeat = self._now()
        h.alive = True

    def check_failures(self) -> list[int]:
        """Mark hosts that missed the heartbeat window; return newly dead."""
        t = self._now()
        newly_dead = []
        for h in self.hosts.values():
            if h.alive and t - h.last_heartbeat > self.timeout:
                h.alive = False
                newly_dead.append(h.host_id)
        return newly_dead

    def alive_hosts(self) -> list[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]

    def elastic_mesh_shape(self, chips_per_host: int,
                           model_parallelism: int) -> tuple[int, int]:
        """Largest (data, model) mesh on the surviving hosts.

        Keeps TP fixed (model_parallelism is arch-determined) and shrinks
        the data axis to the largest power-of-two that fits — checkpoint
        restore handles the resharding.
        """
        chips = len(self.alive_hosts()) * chips_per_host
        data = max(chips // model_parallelism, 1)
        p = 1
        while p * 2 <= data:
            p *= 2
        return (p, model_parallelism)


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-skip for slow hosts in the data pipeline.

    Hosts that miss the per-step deadline contribute no microbatch this
    step; the gradient mean is rescaled by the surviving fraction (loss
    estimate stays unbiased; throughput is protected). `max_skip_frac`
    bounds the quality impact.
    """
    deadline_s: float = 30.0
    max_skip_frac: float = 0.25

    def select(self, arrival_times: dict[int, float]) -> tuple[list[int], float]:
        """arrival_times: host -> seconds to produce its shard.

        Returns (hosts to include, gradient rescale factor).
        """
        n = len(arrival_times)
        on_time = [h for h, t in arrival_times.items()
                   if t <= self.deadline_s]
        min_keep = int(n * (1.0 - self.max_skip_frac) + 0.999)
        if len(on_time) < min_keep:
            # too many stragglers: wait for the fastest min_keep instead
            ranked = sorted(arrival_times, key=arrival_times.get)
            on_time = ranked[:min_keep]
        rescale = n / max(len(on_time), 1)
        return sorted(on_time), rescale


class TrainingSupervisor:
    """Glue: run_step with checkpoint/restart + elastic recovery.

    `run()` drives a step function and simulated host events; on failure it
    re-meshes and resumes from the latest checkpoint. Used by the fault-
    tolerance tests; launch/train.py wires the same pieces to real steps.
    """

    def __init__(self, coordinator: Coordinator, save_every: int,
                 save_fn, restore_fn):
        self.coord = coordinator
        self.save_every = save_every
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.restarts = 0

    def run(self, state, step_fn, n_steps: int, start_step: int = 0,
            events: dict[int, Callable] | None = None):
        step = start_step
        while step < n_steps:
            if events and step in events:
                events.pop(step)(self.coord)
            dead = self.coord.check_failures()
            if dead:
                self.restarts += 1
                state, step = self.restore_fn()
                continue
            state = step_fn(state, step)
            step += 1
            if step % self.save_every == 0:
                self.save_fn(state, step)
        return state, step
