"""Mesh hints: lets mesh-agnostic model code opt into explicit sharding.

Model blocks (MoE EP, sequence parallelism) check `get_hints()` at trace
time; when the launcher wraps the step function in `use_hints(mesh)`, they
emit shard_map / with_sharding_constraint versions, otherwise they stay
pure data-parallel-agnostic jnp (the path unit tests exercise).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import NamedTuple

from jax.sharding import Mesh


class MeshHints(NamedTuple):
    mesh: Mesh
    data_axes: tuple[str, ...]
    model_axis: str | None
    fsdp: bool = False


_HINTS: contextvars.ContextVar[MeshHints | None] = contextvars.ContextVar(
    "repro_mesh_hints", default=None)


def get_hints() -> MeshHints | None:
    return _HINTS.get()


@contextlib.contextmanager
def use_hints(mesh: Mesh, fsdp: bool = False):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_axis = "model" if "model" in mesh.shape else None
    token = _HINTS.set(MeshHints(mesh, data_axes, model_axis, fsdp))
    try:
        yield
    finally:
        _HINTS.reset(token)
