"""Sharding rules: parameter/batch/cache PartitionSpecs for the production
mesh, with divisibility-aware fallbacks.

Policy (DP over pod+data, TP/EP over model):
  * params replicate over (pod, data); their widest TP-able dim shards over
    "model" — attention heads, MLP hidden, experts, vocab; norms replicate.
  * stacked scan parameters carry a leading n_repeats axis that never shards.
  * batch shards over (pod, data) on the batch dim.
  * KV caches shard batch -> data axes, then kv-heads -> model when
    divisible, else the sequence dim -> model (the long-context/small-kv
    regime, e.g. gemma3-1b's single KV head or global_batch=1 decoding).

Every rule is a *request*: `_ok` guards divisibility, so any arch lowers on
any mesh, degrading to replication instead of erroring.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PSpec



def _axsize(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        out = 1
        for a in ax:
            out *= mesh.shape[a]
        return out
    return mesh.shape[ax]


def _ok(dim: int, mesh: Mesh, ax) -> bool:
    s = _axsize(mesh, ax)
    return s > 1 and dim % s == 0 and dim >= s


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
                stacked: bool) -> PSpec:
    """Pick the TP spec for one parameter leaf by name + shape."""
    dims: list = [None] * len(shape)
    off = 1 if stacked else 0  # leading scan-stack axis never shards

    def try_shard(rel_axis: int) -> bool:
        i = off + rel_axis
        if i < len(shape) and _ok(shape[i], mesh, "model"):
            dims[i] = "model"
            return True
        return False

    name = path.split("/")[-1]
    if name in ("wq",):                 # (D, H, Dh)
        _ = try_shard(1) or try_shard(2) or try_shard(0)
    elif name in ("wk", "wv"):          # (D, K, Dh)
        _ = try_shard(1) or try_shard(2) or try_shard(0)
    elif name == "wo" and "attn" in path:   # (H, Dh, D)
        _ = try_shard(0) or try_shard(2)
    elif name in ("wi_gate", "wi_up"):  # (D, F) or (E, D, de)
        _ = try_shard(len(shape) - off - 1) if len(shape) - off == 2 \
            else try_shard(0)
        if dims.count("model") == 0 and len(shape) - off == 3:
            _ = try_shard(2)
    elif name == "wo":                  # mlp (F, D) / moe (E, de, d)
        _ = try_shard(0)
    elif name == "router":              # (D, E)
        _ = try_shard(1)
    elif name in ("embed", "lm_head", "codebook_embed", "codebook_head"):
        # shard the vocab dim
        vdim = {"embed": 0, "lm_head": 1,
                "codebook_embed": 1, "codebook_head": 2}[name]
        _ = try_shard(vdim)
    elif name == "in_proj":             # ssm (D, P)
        _ = try_shard(1) or try_shard(0)
    elif name == "out_proj":            # ssm (di, D)
        _ = try_shard(0) or try_shard(1)
    elif name in ("w1", "w2"):          # vision projector
        _ = try_shard(1)
    # everything else (norms, conv, scalars) replicates
    return PSpec(*dims)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _extend_fsdp(spec: PSpec, shape, mesh: Mesh, stacked: bool) -> PSpec:
    """ZeRO/FSDP: additionally shard the largest free dim over the data
    axes.  pjit materializes full values at use sites (per-layer-group
    all-gather under the scan), keeping resident state 1/|data| as large —
    required for fp32-Adam 27B/235B models on 16 GiB HBM (EXPERIMENTS §Perf
    iteration A5).
    """
    daxes = data_axes(mesh)
    dims = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, d in enumerate(dims):
        if d is not None or (stacked and i == 0):
            continue
        if _ok(shape[i], mesh, daxes) and shape[i] > best_size:
            best, best_size = i, shape[i]
    if best is not None:
        dims[best] = daxes
    return PSpec(*dims)


def param_shardings(mesh: Mesh, params_shape: Any, tp: bool = True,
                    fsdp: bool = False) -> Any:
    """NamedSharding pytree for a params (or ShapeDtypeStruct) pytree.

    tp=False replicates every parameter (the dp_only policy for models too
    small to amortize tensor parallelism); fsdp=True additionally shards
    over the data axes (models too big for TP-only residency).
    """
    def rule(path, leaf):
        if not tp:
            return NamedSharding(mesh, PSpec())
        ps = _path_str(path)
        stacked = "segments" in ps
        spec = _param_spec(ps, leaf.shape, mesh, stacked)
        if fsdp:
            spec = _extend_fsdp(spec, leaf.shape, mesh, stacked)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_state_shardings(mesh: Mesh, state_shape: Any, tp: bool = True,
                        fsdp: bool = False) -> Any:
    """Optimizer state: step replicates; mu/nu mirror the param rules."""
    def rule(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0 or "step" in ps or not tp:
            return NamedSharding(mesh, PSpec())
        stacked = "segments" in ps
        spec = _param_spec(ps, leaf.shape, mesh, stacked)
        if fsdp:
            spec = _extend_fsdp(spec, leaf.shape, mesh, stacked)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(rule, state_shape)


def batch_shardings(mesh: Mesh, batch_shape: Any,
                    batch_axes: tuple[str, ...] | None = None) -> Any:
    """Token/patch batches: shard dim 0 (batch) over (pod, data) — or over
    `batch_axes` (e.g. including "model" under the dp_only policy)."""
    daxes = batch_axes if batch_axes is not None else data_axes(mesh)

    def rule(_, leaf):
        if leaf.ndim >= 1 and _ok(leaf.shape[0], mesh, daxes):
            return NamedSharding(mesh, PSpec(daxes))
        # fall back to single-axis data sharding
        if leaf.ndim >= 1 and _ok(leaf.shape[0], mesh, "data"):
            return NamedSharding(mesh, PSpec("data"))
        return NamedSharding(mesh, PSpec())
    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_shardings(mesh: Mesh, cache_shape: Any) -> Any:
    """KV / SSM caches (leading n_rep axis, then batch)."""
    daxes = data_axes(mesh)

    def rule(path, leaf):
        ps = _path_str(path)
        dims: list = [None] * leaf.ndim
        # leaf layouts: kv (n_rep, B, S, K, Dh); ssm h (n_rep, B, nh, hd, st);
        # conv (n_rep, B, W, C)
        if leaf.ndim >= 2:
            if _ok(leaf.shape[1], mesh, daxes):
                dims[1] = daxes
            elif _ok(leaf.shape[1], mesh, "data"):
                dims[1] = "data"
        last = ps.split("/")[-1]
        if last in ("k", "v") and leaf.ndim == 5:
            if _ok(leaf.shape[3], mesh, "model"):
                dims[3] = "model"        # kv heads
            elif _ok(leaf.shape[2], mesh, "model"):
                dims[2] = "model"        # sequence (small-kv / long-context)
        elif last == "h" and leaf.ndim == 5:
            if _ok(leaf.shape[2], mesh, "model"):
                dims[2] = "model"        # ssm heads
        elif last == "conv" and leaf.ndim == 4:
            if _ok(leaf.shape[3], mesh, "model"):
                dims[3] = "model"        # conv channels
        return NamedSharding(mesh, PSpec(*dims))
    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def logits_sharding(mesh: Mesh, batched: bool = True) -> NamedSharding:
    daxes = data_axes(mesh)
    return NamedSharding(mesh, PSpec(daxes if batched else None))


def with_shardings(shapes: Any, shardings: Any) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct pytree (for .lower())."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
