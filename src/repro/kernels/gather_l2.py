"""Pallas TPU kernel: gather-fused paired distances.

The §Perf analysis of the GRNND build (EXPERIMENTS.md cell C) shows the
dominant bytes are the materialized gathers x[ni], x[nj] — (M, D) matrices
written to and re-read from HBM just to be subtracted.  On TPU the gather
can instead be fused into the distance computation with scalar-prefetched
indices: each grid step DMAs the two needed rows HBM->VMEM directly
(index-dependent BlockSpec index_map), squares-and-reduces on the VPU, and
writes one scalar block.  The (M, D) intermediates never exist.

HBM traffic: 2·M·D·4 bytes of reads + M·4 writes — versus the unfused
2·(M·D reads + M·D writes + M·D re-reads) ≈ 3x reduction, plus the removal
of two big HBM buffers.

Validated under interpret=True against ref.rowwise_sqdist_ref on gathered
rows (tests/test_kernels_gather.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_l2_kernel(ni_ref, nj_ref, *refs, quantized: bool):
    """Grid: (M,). xi/xj blocks are single rows DMA'd per prefetched index.

    `quantized` (the precision ladder, DESIGN.md §8) is a trace-time flag:
    the int8 variant carries (1, D) scale/offset operands, and both DMA'd
    rows are dequantized with the same elementwise formula as
    `ref.dequant_rows` before the subtract-square-reduce — bitwise oracle
    parity preserved.
    """
    if quantized:
        xi_ref, xj_ref, scale_ref, offset_ref, o_ref = refs
    else:
        scale_ref = offset_ref = None
        xi_ref, xj_ref, o_ref = refs
    xi = xi_ref[...].astype(jnp.float32)
    xj = xj_ref[...].astype(jnp.float32)
    if quantized:
        xi = xi * scale_ref[...] + offset_ref[...]
        xj = xj * scale_ref[...] + offset_ref[...]
    diff = xi - xj
    o_ref[...] = jnp.sum(diff * diff, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_sqdist_pallas(
    x: jnp.ndarray,
    ni: jnp.ndarray,
    nj: jnp.ndarray,
    scale: jnp.ndarray | None = None,
    offset: jnp.ndarray | None = None,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """d(x[ni[m]], x[nj[m]]) for m in [0, M) without materialized gathers.

    x (N, D) stays in HBM (ANY memory space); per grid step the BlockSpec
    index_map selects row ni[m] / nj[m] via the scalar-prefetched index
    arrays.  Invalid indices (< 0) are clamped by the caller's mask.
    scale/offset are the precision ladder's optional (D,) per-dim dequant
    of the stored x rows (None = float storage).
    """
    m = ni.shape[0]
    n, d = x.shape
    quantized = scale is not None
    ni = jnp.clip(ni.astype(jnp.int32), 0, n - 1)
    nj = jnp.clip(nj.astype(jnp.int32), 0, n - 1)

    q_ops, q_specs = (), []
    if quantized:
        q_ops = tuple(v.astype(jnp.float32).reshape(1, d)
                      for v in (scale, offset))
        q_specs = [pl.BlockSpec((1, d),
                                lambda i, ni_ref, nj_ref: (0, 0))] * 2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # (ni, nj) land as index operands
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ni_ref, nj_ref: (ni_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, ni_ref, nj_ref: (nj_ref[i], 0)),
        ] + q_specs,
        out_specs=pl.BlockSpec((1,), lambda i, ni_ref, nj_ref: (i,)),
    )
    out = pl.pallas_call(
        functools.partial(_gather_l2_kernel, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(ni, nj, x, x, *q_ops)
    return out
