"""Dispatching wrappers for the Pallas kernels.

Backend policy:
  * "pallas"    — real pl.pallas_call lowering on TPU.  Off-TPU (CPU CI,
                  local debugging) it degrades to interpret mode so the
                  same code path still runs end-to-end.
  * "interpret" — pallas_call(interpret=True): executes the kernel body in
                  Python; used by tests on this CPU container to validate the
                  kernels against the ref.py oracles.
  * "ref"       — pure-jnp oracle; the fast path on CPU (XLA:CPU) and the
                  numerical ground truth.  "xla" is accepted as an alias.
  * "auto"      — pallas on TPU, ref elsewhere.

Selection: `set_backend()` at runtime, or the REPRO_KERNEL_BACKEND
environment variable at import time (see README.md §Backend selection).
"""
from __future__ import annotations

import contextlib
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.gather_l2 import gather_sqdist_pallas
from repro.kernels.pairwise_l2 import pairwise_sqdist_pallas, rowwise_sqdist_pallas
from repro.kernels.rng_round import rng_round_pallas
from repro.kernels.search_expand import search_expand_pallas
from repro.kernels.topr_merge import topr_merge_pallas

_VALID = ("auto", "pallas", "interpret", "ref", "xla")


def _parts(x):
    """(data, scale, offset) of a dataset operand.

    Every distance entry point accepts either a plain (N, D) array or a
    `core.vecstore.VectorStore` (the precision ladder, DESIGN.md §8).
    Duck-typed on the store's field names rather than an isinstance so this
    module needs no import from the core package (kernels sit below core
    in the layering).
    """
    if hasattr(x, "scale") and hasattr(x, "data"):
        return x.data, x.scale, x.offset
    return x, None, None


def _normalize(backend: str) -> str:
    assert backend in _VALID, f"backend must be one of {_VALID}, got {backend!r}"
    return "ref" if backend == "xla" else backend


_BACKEND = _normalize(os.environ.get("REPRO_KERNEL_BACKEND", "auto"))


def set_backend(backend: str) -> None:
    global _BACKEND
    _BACKEND = _normalize(backend)


def get_backend() -> str:
    if _BACKEND != "auto":
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@contextlib.contextmanager
def backend(name: str):
    """Scoped backend override (restores the previous selection on exit)."""
    global _BACKEND
    prev = _BACKEND
    set_backend(name)
    try:
        yield
    finally:
        _BACKEND = prev


def effective_backend() -> str:
    """The backend that will actually execute: real lowering only on TPU;
    "pallas" elsewhere falls back to interpret so CPU CI exercises the
    identical kernel bodies."""
    b = get_backend()
    if b == "pallas" and jax.default_backend() != "tpu":
        return "interpret"
    return b


def _interpret() -> bool:
    return effective_backend() == "interpret"


def pairwise_sqdist(x, y) -> jnp.ndarray:
    """(M,D) x (N,D) -> (M,N) squared L2, fp32.

    Either side may be a VectorStore (fused dequant in the kernel tiles).
    """
    xd, xs, xo = _parts(x)
    yd, ys, yo = _parts(y)
    if get_backend() == "ref":
        return _ref.pairwise_sqdist_ref(xd, yd, xs, xo, ys, yo)
    return pairwise_sqdist_pallas(xd, yd, xs, xo, ys, yo,
                                  interpret=_interpret())


def rowwise_sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(M,D) x (M,D) -> (M,) squared L2 of corresponding rows, fp32."""
    if get_backend() == "ref":
        return _ref.rowwise_sqdist_ref(x, y)
    return rowwise_sqdist_pallas(x, y, interpret=_interpret())


def topr_merge(ids: jnp.ndarray, dists: jnp.ndarray, r: int):
    """(B,W) candidate rows -> (B,r) closest unique entries. See ref.topr_merge_ref."""
    if get_backend() == "ref":
        return _ref.topr_merge_ref(ids, dists, r)
    return topr_merge_pallas(ids, dists, r, interpret=_interpret())


def search_expand(x, queries, nbrs, table, valid=None, vwords=None,
                  fwords=None):
    """Fused beam-search expansion step: (ids, dists, fresh[, allowed]).

    See ref.search_expand_ref for semantics; the pallas path fuses the
    neighbor-vector gather, query->neighbor distances, the visited-table
    probe, and the optional tombstone-validity probe into one VMEM-resident
    pass (kernels/search_expand.py).  `valid` is the dynamic index's (N,)
    vertex-validity mask (None = all live, the static-index path).  `x`
    may be a VectorStore (fused dequant on the row DMA).  `vwords`/`fwords`
    are the optional filtered-search predicate (core/labels.py): packed
    (N, W) vertex label words + (Q, W) query allowed words; when given,
    a fourth `allowed` output is appended (route-through semantics).
    """
    xd, xs, xo = _parts(x)
    if get_backend() == "ref":
        return _ref.search_expand_ref(xd, queries, nbrs, table, valid,
                                      xs, xo, vwords, fwords)
    return search_expand_pallas(xd, queries, nbrs, table, valid, xs, xo,
                                vwords, fwords, interpret=_interpret())


def rng_propagation_round(x, ids, dists, si, sj):
    """Fused disordered propagation round: (dst, src, dij, kill).

    See ref.rng_round_ref for semantics; the pallas path fuses the
    neighbor-vector gather, pair distances, RNG criterion, and kill-mask
    emission into one VMEM-resident pass (kernels/rng_round.py).  `x` may
    be a VectorStore (fused dequant on the row DMA).
    """
    xd, xs, xo = _parts(x)
    if get_backend() == "ref":
        return _ref.rng_round_ref(xd, ids, dists, si, sj, xs, xo)
    return rng_round_pallas(xd, ids, dists, si, sj, xs, xo,
                            interpret=_interpret())


def gather_sqdist(x, ni, nj) -> jnp.ndarray:
    """d(x[ni[m]], x[nj[m]]) for m in [0, M) -> (M,) fp32.

    See ref.gather_sqdist_ref; the pallas path (kernels/gather_l2.py) DMAs
    the two rows per step straight into VMEM — no materialized (M, D)
    gathers.  `x` may be a VectorStore (fused dequant on the row DMA).
    """
    xd, xs, xo = _parts(x)
    if get_backend() == "ref":
        return _ref.gather_sqdist_ref(xd, ni, nj, xs, xo)
    return gather_sqdist_pallas(xd, ni, nj, xs, xo, interpret=_interpret())
