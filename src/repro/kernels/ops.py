"""Dispatching wrappers for the Pallas kernels.

Backend policy:
  * "pallas"    — real pl.pallas_call lowering (TPU).
  * "interpret" — pallas_call(interpret=True): executes the kernel body in
                  Python; used by tests on this CPU container to validate the
                  kernels against the ref.py oracles.
  * "ref"       — pure-jnp oracle; the fast path on CPU (XLA:CPU) and the
                  numerical ground truth.
  * "auto"      — pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.pairwise_l2 import pairwise_sqdist_pallas, rowwise_sqdist_pallas
from repro.kernels.topr_merge import topr_merge_pallas

_BACKEND = "auto"


def set_backend(backend: str) -> None:
    global _BACKEND
    assert backend in ("auto", "pallas", "interpret", "ref")
    _BACKEND = backend


def get_backend() -> str:
    if _BACKEND != "auto":
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def pairwise_sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(M,D) x (N,D) -> (M,N) squared L2, fp32."""
    backend = get_backend()
    if backend == "ref":
        return _ref.pairwise_sqdist_ref(x, y)
    return pairwise_sqdist_pallas(x, y, interpret=(backend == "interpret"))


def rowwise_sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(M,D) x (M,D) -> (M,) squared L2 of corresponding rows, fp32."""
    backend = get_backend()
    if backend == "ref":
        return _ref.rowwise_sqdist_ref(x, y)
    return rowwise_sqdist_pallas(x, y, interpret=(backend == "interpret"))


def topr_merge(ids: jnp.ndarray, dists: jnp.ndarray, r: int):
    """(B,W) candidate rows -> (B,r) closest unique entries. See ref.topr_merge_ref."""
    backend = get_backend()
    if backend == "ref":
        return _ref.topr_merge_ref(ids, dists, r)
    return topr_merge_pallas(ids, dists, r, interpret=(backend == "interpret"))
