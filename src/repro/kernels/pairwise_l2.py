"""Pallas TPU kernel: blocked pairwise squared-L2 distance.

This is the paper's hot spot (GRNND §3.4, WARP_DISTANCE).  On the GPU a warp
strides the vector dimensions and tree-reduces with __shfl_down; the TPU-
native formulation feeds the MXU instead: for a (BM, BK) tile of X and a
(BN, BK) tile of Y the partial squared distance is

    ||x||^2_slab + ||y||^2_slab - 2 * x @ y.T

accumulated over D-slabs in fp32.  BlockSpecs keep one X slab, one Y slab and
the (BM, BN) accumulator resident in VMEM; slab size is chosen so the working
set stays well under the ~16 MiB/core budget while the contraction dimension
remains a multiple of the 128-lane MXU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _pairwise_kernel(x_ref, y_ref, *refs, xq: bool, yq: bool):
    """Grid: (M/BM, N/BN, D/BK).  Accumulates over the k axis.

    `xq`/`yq` are trace-time flags for the precision ladder (DESIGN.md §8):
    a quantized side carries a (1, BK) scale and offset slab, and its rows
    are dequantized in VMEM right after the fp32 widen — the same
    elementwise `dequant_rows` formula as the ref.py oracle, so the fused
    dequant changes nothing about oracle parity.  The fp32/bf16 path
    compiles without the extra operands.
    """
    it = iter(refs)
    sx_ref, ox_ref = (next(it), next(it)) if xq else (None, None)
    sy_ref, oy_ref = (next(it), next(it)) if yq else (None, None)
    o_ref = next(it)
    k = pl.program_id(2)
    x = x_ref[...].astype(jnp.float32)  # (BM, BK)
    y = y_ref[...].astype(jnp.float32)  # (BN, BK)
    if xq:
        x = x * sx_ref[...] + ox_ref[...]
    if yq:
        y = y * sy_ref[...] + oy_ref[...]
    xx = jnp.sum(x * x, axis=-1, keepdims=True)                    # (BM, 1)
    yy = jnp.sum(y * y, axis=-1)[None, :]                          # (1, BN)
    xy = jax.lax.dot_general(
        x, y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                              # (BM, BN)
    partial = xx + yy - 2.0 * xy

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += partial


def _pad_to(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def pairwise_sqdist_pallas(
    x: jnp.ndarray,
    y: jnp.ndarray,
    x_scale: jnp.ndarray | None = None,
    x_offset: jnp.ndarray | None = None,
    y_scale: jnp.ndarray | None = None,
    y_offset: jnp.ndarray | None = None,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Squared L2 distances between rows of x (M,D) and y (N,D) -> (M,N) fp32.

    Either side may be stored quantized (int8 + per-dim (D,) scale/offset,
    the precision ladder): the dequant is fused into the tile load.  The
    scale/offset slabs are ZERO-padded along D, so padded columns dequant
    to exactly 0 and contribute nothing to any distance.
    """
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    bk = min(bk, max(128, d))
    xq = x_scale is not None
    yq = y_scale is not None

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    yp = _pad_to(_pad_to(y, 0, bn), 1, bk)
    mp, dp = xp.shape
    np_, _ = yp.shape

    def _qslab(v):  # (D,) -> (1, dp), zero-padded
        return _pad_to(v.astype(jnp.float32).reshape(1, d), 1, bk)

    qspec = pl.BlockSpec((1, bk), lambda i, j, k: (0, k))
    ops_q, specs_q = [], []
    if xq:
        ops_q += [_qslab(x_scale), _qslab(x_offset)]
        specs_q += [qspec, qspec]
    if yq:
        ops_q += [_qslab(y_scale), _qslab(y_offset)]
        specs_q += [qspec, qspec]

    grid = (mp // bm, np_ // bn, dp // bk)
    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, xq=xq, yq=yq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ] + specs_q,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, yp, *ops_q)
    return jnp.maximum(out[:m, :n], 0.0)


def _rowwise_kernel(x_ref, y_ref, o_ref):
    """Grid: (M/BM, D/BK). Row-paired squared distance, accumulated over k."""
    k = pl.program_id(1)
    diff = x_ref[...].astype(jnp.float32) - y_ref[...].astype(jnp.float32)
    partial = jnp.sum(diff * diff, axis=-1, keepdims=True)  # (BM, 1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def rowwise_sqdist_pallas(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = 256,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Row-paired squared L2: x (M,D), y (M,D) -> (M,) fp32."""
    m, d = x.shape
    assert y.shape == x.shape
    bk = min(bk, max(128, d))

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    yp = _pad_to(_pad_to(y, 0, bm), 1, bk)
    mp, dp = xp.shape

    grid = (mp // bm, dp // bk)
    out = pl.pallas_call(
        _rowwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, 0]
