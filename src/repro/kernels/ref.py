"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real lowering on TPU) and the fallback implementation used when the
Pallas path is disabled (e.g. CPU benchmarking, where interpret mode would be
orders of magnitude slower than XLA:CPU).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "pairwise_sqdist_ref",
    "rowwise_sqdist_ref",
    "topr_merge_ref",
]


def pairwise_sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distances between all rows of x (M,D) and y (N,D) -> (M,N).

    Uses the MXU-friendly decomposition ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y
    with fp32 accumulation, clamped at zero (the decomposition can go slightly
    negative in floating point).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)  # (M, 1)
    yy = jnp.sum(y * y, axis=-1)[None, :]        # (1, N)
    xy = x @ y.T                                  # (M, N)
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def rowwise_sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distance between corresponding rows of x and y: (M,D)x(M,D)->(M,)."""
    d = x.astype(jnp.float32) - y.astype(jnp.float32)
    return jnp.sum(d * d, axis=-1)


def topr_merge_ref(
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    r: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge candidate rows into the R closest unique entries per row.

    Args:
      ids:   (B, W) int32 candidate ids; -1 marks an empty slot.
      dists: (B, W) float32 distances to the row's owner; +inf for empty.
      r:     output pool capacity.

    Returns (out_ids (B, r) int32, out_dists (B, r) float32): per row, the r
    closest *unique* valid ids (duplicates keep their first/min-distance
    occurrence); empty slots hold (-1, +inf).

    This is the deterministic TPU-side replacement for the paper's
    WARP_INSERT (ballot dedup + replace-farthest-if-closer): keeping the R
    closest of the union dominates arrival-order replacement.
    """
    ids = ids.astype(jnp.int32)
    dists = jnp.where(ids < 0, jnp.inf, dists.astype(jnp.float32))

    # Dedup: an entry is a duplicate if an earlier slot (or an equal-position
    # slot with smaller dist) holds the same id.  O(W^2) mask — W is small.
    same = ids[..., :, None] == ids[..., None, :]                    # (B,W,W)
    earlier = jnp.tril(jnp.ones(same.shape[-2:], dtype=bool), k=-1)  # j<i
    dup = jnp.any(same & earlier[None, ...], axis=-1)                # (B,W)
    dists = jnp.where(dup, jnp.inf, dists)
    ids = jnp.where(dup, -1, ids)

    order = jnp.argsort(dists, axis=-1)[..., :r]
    out_d = jnp.take_along_axis(dists, order, axis=-1)
    out_i = jnp.take_along_axis(ids, order, axis=-1)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_i, out_d
