"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real lowering on TPU) and the fallback implementation used when the
Pallas path is disabled (e.g. CPU benchmarking, where interpret mode would be
orders of magnitude slower than XLA:CPU).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "pairwise_sqdist_ref",
    "rowwise_sqdist_ref",
    "topr_merge_ref",
    "rng_round_ref",
    "search_expand_ref",
    "gather_sqdist_ref",
    "dequant_rows",
    "visited_probe_positions",
    "HASH_PROBES",
]

# Linear-probe window of the open-addressed visited table (DESIGN.md §6.1);
# the single source shared by the oracle, the Pallas kernel, and the
# table-insert path in core/search.py.
HASH_PROBES = 8


def dequant_rows(data: jnp.ndarray, scale, offset) -> jnp.ndarray:
    """The precision ladder's dequant (DESIGN.md §8): fp32 widen, then the
    per-dim affine correction.  scale/offset None = a float rung (fp32 or
    bf16 storage), where the widen alone is exact.

    This is the single formula shared by `core.vecstore.VectorStore`, every
    oracle below, and — inlined operation-for-operation — the Pallas kernel
    bodies: it is elementwise, so oracle and kernel produce bitwise-equal
    fp32 rows from the same stored bytes (tests/test_precision.py).
    """
    x = data.astype(jnp.float32)
    if scale is not None:
        x = x * scale + offset
    return x


def pairwise_sqdist_ref(
    x: jnp.ndarray,
    y: jnp.ndarray,
    x_scale=None, x_offset=None,
    y_scale=None, y_offset=None,
) -> jnp.ndarray:
    """Squared L2 distances between all rows of x (M,D) and y (N,D) -> (M,N).

    Uses the MXU-friendly decomposition ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y
    with fp32 accumulation, clamped at zero (the decomposition can go slightly
    negative in floating point).  The optional per-side (D,) scale/offset are
    the precision ladder's fused dequant (applied to the stored rows before
    the distance math — see `dequant_rows`).
    """
    x = dequant_rows(x, x_scale, x_offset)
    y = dequant_rows(y, y_scale, y_offset)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)  # (M, 1)
    yy = jnp.sum(y * y, axis=-1)[None, :]        # (1, N)
    xy = x @ y.T                                  # (M, N)
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def rowwise_sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distance between corresponding rows of x and y: (M,D)x(M,D)->(M,)."""
    d = x.astype(jnp.float32) - y.astype(jnp.float32)
    return jnp.sum(d * d, axis=-1)


def gather_sqdist_ref(
    x: jnp.ndarray,
    ni: jnp.ndarray,
    nj: jnp.ndarray,
    scale=None, offset=None,
) -> jnp.ndarray:
    """d(x[ni[m]], x[nj[m]]) for m in [0, M) — oracle for gather_l2.py.

    Indices < 0 are clamped to row 0 (matching the kernel's clamp; callers
    mask invalid entries themselves).  scale/offset are the precision
    ladder's per-dim dequant of the stored x rows.
    """
    n = x.shape[0]
    xi = dequant_rows(x[jnp.clip(ni, 0, n - 1)], scale, offset)
    xj = dequant_rows(x[jnp.clip(nj, 0, n - 1)], scale, offset)
    d = xi - xj
    return jnp.sum(d * d, axis=-1)


def rng_round_ref(
    x: jnp.ndarray,
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    si: jnp.ndarray,
    sj: jnp.ndarray,
    scale=None, offset=None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One disordered RNG propagation round (GRNND Alg. 4 lines 4-10).

    Args:
      x:     (N, D) dataset (fp32/bf16/int8 per the precision ladder).
      ids:   (C, R) int32 pool ids; -1 marks an empty slot.
      dists: (C, R) float32 distances to the owning vertex; +inf for empty.
      si/sj: (C, P) int32 sampled slot indices in [0, R) — drawn by the
             caller so every backend evaluates the identical pairs.
      scale/offset: optional (D,) per-dim dequant of the stored x rows
             (`dequant_rows`); None = float storage.

    Returns (dst (C,P) i32, src (C,P) i32, dij (C,P) f32, kill (C,R) bool).
    For each sampled pair that is valid (both slots occupied, distinct
    neighbors) and passes the RNG criterion d(n_i, n_j) < max(d(v, n_i),
    d(v, n_j)), the farther endpoint `src` is redirected into the closer
    endpoint `dst`'s pool and the farther endpoint's slot is killed;
    missed pairs carry dst = -1.
    """
    c, r = ids.shape
    p = si.shape[1]
    ni = jnp.take_along_axis(ids, si, axis=1)
    nj = jnp.take_along_axis(ids, sj, axis=1)
    dvi = jnp.take_along_axis(dists, si, axis=1)
    dvj = jnp.take_along_axis(dists, sj, axis=1)
    valid = (ni >= 0) & (nj >= 0) & (ni != nj)

    xi = dequant_rows(x[jnp.clip(ni, 0).reshape(-1)], scale, offset)
    xj = dequant_rows(x[jnp.clip(nj, 0).reshape(-1)], scale, offset)
    diff = xi - xj
    dij = jnp.sum(diff * diff, axis=-1).reshape(c, p)

    hit = valid & (dij < jnp.maximum(dvi, dvj))  # RNG criterion (eq. 2)
    i_is_far = dvi > dvj
    far = jnp.where(i_is_far, ni, nj)
    close = jnp.where(i_is_far, nj, ni)
    far_slot = jnp.where(i_is_far, si, sj)

    dst = jnp.where(hit, close, -1)
    kill = jnp.zeros((c, r), jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[:, None], (c, p))
    kill = kill.at[rows, far_slot].max(hit.astype(jnp.int32))
    return dst, far, dij, kill.astype(bool)


def visited_probe_positions(ids: jnp.ndarray, h: int) -> jnp.ndarray:
    """Probe positions (..., HASH_PROBES) of ids in an H-slot visited table.

    Identity-mod base hash + linear probing: slot l of id v is
    (v % H + l) % H.  Vertex ids are arbitrary labels, so identity-mod is
    as uniform as any mix for permutation-invariant id assignment — and it
    is injective whenever H >= N, which makes `visited_cap >= N` provably
    collision-free (the dense-parity guarantee, DESIGN.md §6.1).
    """
    base = jnp.clip(ids.astype(jnp.int32), 0) % h
    return (base[..., None] +
            jnp.arange(HASH_PROBES, dtype=jnp.int32)) % h


def search_expand_ref(
    x: jnp.ndarray,
    queries: jnp.ndarray,
    nbrs: jnp.ndarray,
    table: jnp.ndarray,
    valid: jnp.ndarray | None = None,
    scale=None, offset=None,
    vwords: jnp.ndarray | None = None,
    fwords: jnp.ndarray | None = None,
):
    """One fused beam-search expansion step (see kernels/search_expand.py).

    Args:
      x:       (N, D) dataset (fp32/bf16/int8; `scale`/`offset` are the
               optional per-dim dequant of the stored rows — queries stay
               fp32, only the dataset side rides the precision ladder).
      queries: (Q, D) query vectors.
      nbrs:    (Q, R) int32 neighbor ids of each query's selected vertex;
               -1 marks an invalid entry (inactive query / empty slot).
               Width-agnostic: R is the raw pool width or the packed
               degree D of an optimized layout (core/layout.py); packed
               rows keep their sentinels as a tail suffix, which changes
               nothing here (the mask is positionless).
      table:   (Q, H) int32 open-addressed visited table; -1 = empty slot.
      valid:   optional (N,) bool vertex-validity mask (the dynamic index's
               tombstone mask, core/dynamic.py).  A neighbor whose vertex is
               tombstoned is treated exactly like an empty graph slot: it is
               never expanded, scored, or returned — so a later `compact()`
               (which physically drops dead vertices and their in-edges)
               cannot change any search trajectory.  None = all vertices
               live (the static-index path, bit-identical to the pre-mask
               kernel).
      vwords/fwords: the optional filtered-search predicate (core/labels.py,
               DESIGN.md §9): (N, W) packed per-vertex label-bitset words
               and (Q, W) per-query allowed-bitset words.  Semantics are
               ROUTE-THROUGH — a filtered-out neighbor keeps its real id,
               distance, and freshness (it stays fully traversable, per
               GGNN's connectivity-under-masking observation) and is only
               flagged in the extra `allowed` output, which the search
               uses to mask it out of the result heap.  Both or neither
               must be given.

    Returns (ids (Q,R) i32, dists (Q,R) f32, fresh (Q,R) bool): the
    neighbor ids (invalid/dead -> -1), exact squared query->neighbor
    distances (+inf where invalid/dead), and the freshness mask — live AND
    not found in the table's probe window.  False positives are impossible
    (exact keys); a capacity miss only re-marks an already-visited id as
    fresh, which the deduplicating beam merge absorbs.  With the filter
    operands a fourth element `allowed (Q,R) bool` is appended: live AND
    `any(vwords[id] & fwords[q])` — pure int32 bitwise math, so kernel and
    oracle agree bitwise on every precision rung.
    """
    q, r = nbrs.shape
    ok = nbrs >= 0
    if valid is not None:
        ok = ok & valid.astype(bool)[jnp.clip(nbrs, 0)]
    nv = dequant_rows(x[jnp.clip(nbrs, 0).reshape(-1)], scale,
                      offset).reshape(q, r, -1)
    diff = queries.astype(jnp.float32)[:, None, :] - nv
    d = jnp.sum(diff * diff, axis=-1)
    d = jnp.where(ok, d, jnp.inf)

    h = table.shape[1]
    pos = visited_probe_positions(nbrs, h)                    # (Q, R, PL)
    qrows = jnp.arange(q, dtype=jnp.int32)[:, None, None]
    vals = table[qrows, pos]                                  # (Q, R, PL)
    found = jnp.any(vals == nbrs[..., None], axis=-1)
    out = (jnp.where(ok, nbrs, -1), d, ok & ~found)
    if fwords is None:
        return out
    lw = vwords[jnp.clip(nbrs, 0)]                            # (Q, R, W)
    allowed = ok & jnp.any((lw & fwords[:, None, :]) != 0, axis=-1)
    return out + (allowed,)


def topr_merge_ref(
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    r: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge candidate rows into the R closest unique entries per row.

    Args:
      ids:   (B, W) int32 candidate ids; -1 marks an empty slot.
      dists: (B, W) float32 distances to the row's owner; +inf for empty.
      r:     output pool capacity.

    Returns (out_ids (B, r) int32, out_dists (B, r) float32): per row, the r
    closest *unique* valid ids (duplicates keep their first/min-distance
    occurrence); empty slots hold (-1, +inf).

    This is the deterministic TPU-side replacement for the paper's
    WARP_INSERT (ballot dedup + replace-farthest-if-closer): keeping the R
    closest of the union dominates arrival-order replacement.
    """
    ids = ids.astype(jnp.int32)
    dists = jnp.where(ids < 0, jnp.inf, dists.astype(jnp.float32))
    if r > ids.shape[-1]:  # W < r: widen so the output is always (B, r)
        pad = r - ids.shape[-1]
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        dists = jnp.pad(dists, ((0, 0), (0, pad)), constant_values=jnp.inf)

    # Dedup: an entry is a duplicate if an earlier slot (or an equal-position
    # slot with smaller dist) holds the same id.  O(W^2) mask — W is small.
    same = ids[..., :, None] == ids[..., None, :]                    # (B,W,W)
    earlier = jnp.tril(jnp.ones(same.shape[-2:], dtype=bool), k=-1)  # j<i
    dup = jnp.any(same & earlier[None, ...], axis=-1)                # (B,W)
    dists = jnp.where(dup, jnp.inf, dists)
    ids = jnp.where(dup, -1, ids)

    order = jnp.argsort(dists, axis=-1)[..., :r]
    out_d = jnp.take_along_axis(dists, order, axis=-1)
    out_i = jnp.take_along_axis(ids, order, axis=-1)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_i, out_d
