"""Pallas TPU kernel: fused RNG propagation round (GRNND Alg. 4 inner loop).

One disordered propagation round previously lowered to a chain of separate
XLA ops: two `take_along_axis` gathers of pool slots, a materialized
(N·P, D) double gather of neighbor vectors, a `rowwise_sqdist` call, and
two scatters for the kill mask — every intermediate written to and re-read
from HBM, leaving the hot inner round memory-bound (EXPERIMENTS.md §Perf,
cell C and cell F).

This kernel fuses the whole pair-evaluation round.  Per vertex, it

  1. gathers the pool's R neighbor vectors ONCE into a VMEM scratch via
     index-dependent BlockSpecs over scalar-prefetched pool ids (the same
     DMA-gather idiom as `gather_l2.py` — grid (N, R), one row per step);
  2. at the last row of each vertex, evaluates all P sampled slot pairs
     in-register: one-hot slot selection (exact — exactly one hot per
     row, so the f32 matmul is a lossless gather), a (P, D) paired
     squared distance on the MXU/VPU, and the RNG criterion
     d(n_i, n_j) < max(d(v, n_i), d(v, n_j)) (paper eq. 2);
  3. emits the redirect requests (dst = closer endpoint, src = farther
     endpoint, the pair distance) and the per-slot kill mask in one pass.

The (N·P, D) gathered-vector intermediates never exist: HBM traffic per
vertex drops from ~2·P·D reads + 2·P·D writes + 2·P·D re-reads to R·D
reads (pool vectors, each fetched once regardless of how many sampled
pairs touch it) + the small (P,)/(R,) outputs.  See DESIGN.md §3 for
the full memory-layout discussion.

Semantics match `ref.rng_round_ref` bitwise under a common jit context
(the parity tests assert identical kill masks, redirects, and merged
pools): the slot samples si/sj are drawn OUTSIDE the kernel with the
usual jax PRNG so every backend sees the same pairs, the one-hot slot
selection is a lossless gather, and the distance math follows the same
subtract-square-reduce order as `rowwise_sqdist_ref`.

TPU notes: D is zero-padded to the 128-lane width (zero columns do not
change distances); R and P are small (8-64) so the per-pair arrays ride
in single vregs.  Validated under interpret=True on CPU
(tests/test_rng_round.py); real-TPU lowering uses the same code path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rng_round_kernel(ids_pref, xrow_ref, *refs, r: int, p: int,
                      quantized: bool):
    """Grid: (N, R). Step (v, rr) DMAs x[ids[v, rr]] into vecs row rr; the
    pair evaluation runs once per vertex on the final row.

    `quantized` is the precision ladder's trace-time flag (DESIGN.md §8):
    the int8 variant carries (1, D) scale/offset operands and each DMA'd
    row is dequantized as it lands in the fp32 VMEM scratch — the same
    elementwise formula as `ref.dequant_rows`, so bitwise oracle parity is
    preserved.  The float rungs compile without the extra operands.
    """
    del ids_pref  # consumed by the index_maps
    if quantized:
        (scale_ref, offset_ref, ids_ref, dists_ref, si_ref, sj_ref,
         dst_ref, src_ref, dij_ref, kill_ref, vecs_ref) = refs
    else:
        scale_ref = offset_ref = None
        (ids_ref, dists_ref, si_ref, sj_ref,
         dst_ref, src_ref, dij_ref, kill_ref, vecs_ref) = refs
    rr = pl.program_id(1)
    row = xrow_ref[...].astype(jnp.float32)
    if quantized:
        row = row * scale_ref[...] + offset_ref[...]
    vecs_ref[pl.ds(rr, 1), :] = row

    @pl.when(rr == r - 1)
    def _evaluate():
        vecs = vecs_ref[...]                              # (R, D) f32, VMEM
        ids_row = ids_ref[...]                            # (1, R) int32
        d_row = dists_ref[...]                            # (1, R) f32
        # (1, P) -> (P, 1): row-major reshape, no data movement
        si = si_ref[...].reshape(p, 1)
        sj = sj_ref[...].reshape(p, 1)

        slot = jax.lax.broadcasted_iota(jnp.int32, (p, r), 1)
        oi = si == slot                                   # (P, R) one-hot
        oj = sj == slot

        ids_b = jnp.broadcast_to(ids_row, (p, r))
        d_b = jnp.broadcast_to(d_row, (p, r))
        # exactly one hot per row -> the masked sums are exact selections
        # (where, not multiply: empty slots hold inf and 0*inf = nan)
        ni = jnp.sum(jnp.where(oi, ids_b, 0), axis=1, keepdims=True)
        nj = jnp.sum(jnp.where(oj, ids_b, 0), axis=1, keepdims=True)
        dvi = jnp.sum(jnp.where(oi, d_b, 0.0), axis=1, keepdims=True)
        dvj = jnp.sum(jnp.where(oj, d_b, 0.0), axis=1, keepdims=True)

        mm = functools.partial(
            jax.lax.dot_general,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        xi = mm(oi.astype(jnp.float32), vecs)             # (P, D) exact gather
        xj = mm(oj.astype(jnp.float32), vecs)
        diff = xi - xj
        dij = jnp.sum(diff * diff, axis=1, keepdims=True)  # (P, 1)

        valid = (ni >= 0) & (nj >= 0) & (ni != nj)
        hit = valid & (dij < jnp.maximum(dvi, dvj))        # RNG criterion
        i_is_far = dvi > dvj
        far = jnp.where(i_is_far, ni, nj)
        close = jnp.where(i_is_far, nj, ni)
        far_slot = jnp.where(i_is_far, si, sj)             # (P, 1)

        dst_ref[...] = jnp.where(hit, close, -1).reshape(1, p)
        src_ref[...] = far.reshape(1, p)
        dij_ref[...] = dij.reshape(1, p)
        # kill[rr] = any sampled hit whose farther endpoint sits in slot rr
        o_far = (far_slot == slot) & hit                   # (P, R)
        kill_ref[...] = jnp.max(o_far.astype(jnp.int32), axis=0,
                                keepdims=True)             # (1, R)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rng_round_pallas(
    x: jnp.ndarray,
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    si: jnp.ndarray,
    sj: jnp.ndarray,
    scale: jnp.ndarray | None = None,
    offset: jnp.ndarray | None = None,
    *,
    interpret: bool = False,
):
    """Fused propagation round over a (C, R) pool chunk.

    Args:
      x:     (N, D) dataset (stays in HBM; rows are DMA'd on demand;
             fp32/bf16/int8 storage per the precision ladder).
      ids:   (C, R) int32 pool ids, -1 = empty slot.
      dists: (C, R) f32 owner distances, +inf = empty.
      si/sj: (C, P) int32 sampled slot indices in [0, R).
      scale/offset: optional (D,) per-dim dequant of the stored x rows,
             fused into the row DMA (None = float storage).

    Returns (dst (C,P) i32, src (C,P) i32, dij (C,P) f32, kill (C,R) bool):
    the redirect requests (dst = -1 where the pair missed) and the slot
    kill mask — identical to `ref.rng_round_ref`.
    """
    c, r = ids.shape
    n, d = x.shape
    p = si.shape[1]
    quantized = scale is not None
    ids_safe = jnp.clip(ids.astype(jnp.int32), 0, n - 1)

    # Lane-align D for the real TPU lowering only: the zero columns keep
    # distances mathematically unchanged but alter the fp32 reduction tree
    # (~1e-7 relative), so interpret mode — the bitwise-parity harness —
    # skips the pad.  scale/offset pad with ZEROS, so padded columns of a
    # quantized x dequant to exactly 0.
    pad_d = 0 if interpret else (-d) % 128
    xp = jnp.pad(x, ((0, 0), (0, pad_d))) if pad_d else x
    dp = d + pad_d

    q_ops, q_specs = (), []
    if quantized:
        q_ops = tuple(
            jnp.pad(v.astype(jnp.float32).reshape(1, d), ((0, 0), (0, pad_d)))
            for v in (scale, offset))
        q_specs = [pl.BlockSpec((1, dp), lambda v, rr, ids_ref: (0, 0))] * 2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,               # ids_safe lands as index operand
        grid=(c, r),
        in_specs=[
            pl.BlockSpec((1, dp), lambda v, rr, ids_ref: (ids_ref[v, rr], 0)),
        ] + q_specs + [
            pl.BlockSpec((1, r), lambda v, rr, ids_ref: (v, 0)),
            pl.BlockSpec((1, r), lambda v, rr, ids_ref: (v, 0)),
            pl.BlockSpec((1, p), lambda v, rr, ids_ref: (v, 0)),
            pl.BlockSpec((1, p), lambda v, rr, ids_ref: (v, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, p), lambda v, rr, ids_ref: (v, 0)),
            pl.BlockSpec((1, p), lambda v, rr, ids_ref: (v, 0)),
            pl.BlockSpec((1, p), lambda v, rr, ids_ref: (v, 0)),
            pl.BlockSpec((1, r), lambda v, rr, ids_ref: (v, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((r, dp), jnp.float32)],
    )
    dst, src, dij, kill = pl.pallas_call(
        functools.partial(_rng_round_kernel, r=r, p=p, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((c, p), jnp.int32),
            jax.ShapeDtypeStruct((c, p), jnp.int32),
            jax.ShapeDtypeStruct((c, p), jnp.float32),
            jax.ShapeDtypeStruct((c, r), jnp.int32),
        ],
        interpret=interpret,
    )(ids_safe, xp, *q_ops, ids.astype(jnp.int32), dists.astype(jnp.float32),
      si.astype(jnp.int32), sj.astype(jnp.int32))
    return dst, src, dij, kill.astype(bool)
