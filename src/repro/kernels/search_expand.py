"""Pallas TPU kernel: fused beam-search expansion step.

One expansion step of the batched beam search (core/search.py) previously
lowered to the same unfused shape as the old propagation round: a
materialized (Q·R, D) gather of the selected vertex's neighbor vectors, a
`jnp.repeat` of the queries to match, a `rowwise_sqdist` over the pair, and
a separate dense visited-bitmask lookup — every intermediate written to and
re-read from HBM, on the query-serving hot path (EXPERIMENTS.md §Perf
cell E; GGNN's fused gather-and-distance expansion is the GPU analogue).

This kernel fuses the whole step.  Per query q it

  1. gathers the R neighbor vectors of the selected vertex ONCE into a
     VMEM scratch via index-dependent BlockSpecs over the scalar-prefetched
     (clamped) neighbor ids — grid (Q, R), one row per step, the same
     DMA-gather idiom as `rng_round.py`;
  2. at the last row, computes all R query→neighbor squared distances
     in-register (subtract-square-reduce, the `rowwise_sqdist_ref` order);
  3. probes the query's open-addressed visited table (H int32 slots,
     identity-mod hash + linear probe window, DESIGN.md §6.1): the table
     is wrap-extended by PROBES slots outside the kernel, so each id's
     probe window is one contiguous O(PROBES) dynamic slice — membership
     work per id is independent of H — and emits (ids, dists, fresh-mask)
     in one pass;
  4. applies the optional (N,) vertex-validity mask (the dynamic index's
     tombstone mask, core/dynamic.py §DESIGN.md §7): each neighbor's
     validity bit is DMA'd on the same per-row schedule as its vector, and
     a dead neighbor is reported exactly like an empty graph slot
     (id -1, dist +inf, not fresh);
  5. evaluates the optional per-query label predicate (filtered search,
     core/labels.py, DESIGN.md §9): the neighbor's (W,) packed label-bitset
     words ride the same per-row DMA schedule, intersect with the query's
     allowed-bitset block, and emit an extra `allowed` output — ROUTE-
     THROUGH semantics, so ids/dists/fresh are untouched (the filtered-out
     neighbor stays traversable; only the result heap masks it).

The (Q·R, D) gathered-vector and repeated-query intermediates never exist:
HBM traffic per step drops from ~3·(Q·R·D + Q·D·R) read/write/re-read bytes
to R·D reads per query plus the small (Q, R) outputs.

Membership semantics: `fresh[q, j]` is true iff nbrs[q, j] is a valid id
AND the id is NOT stored in the table's probe window — false positives are
impossible (exact int32 keys, not fingerprints), so a hash-capacity miss
can only cause a harmless re-expansion, never a wrongly-skipped vertex.
Table *updates* stay outside the kernel (core/search.py inserts after the
step); the kernel is a pure read.  A (Q, 1) all-empty table turns the probe
into a no-op, which is how the dense-visited path shares this kernel.

Graph-row layout contract (core/layout.py): callers hand this kernel the
ALREADY-GATHERED (Q, R) neighbor-id rows of the selected vertices, so the
optimized index's packed fixed-degree adjacency needs no kernel variant —
R simply becomes the packed degree D.  The packed rows additionally
guarantee -1 sentinels appear only as a tail suffix (rank-ordered valid ids
first), which the kernel tolerates anywhere but the DMA schedule rewards:
a packed row's clamped sentinel gathers are contiguous repeats of row 0
instead of interleaved holes, and the locality renumbering makes the
nb_ref[q, rr] row indices near-sequential across the beam.

Semantics match `ref.search_expand_ref` bitwise under a common jit context
(tests/test_search_parity.py): probe positions follow the same
identity-mod + linear-probe formula and the distance reduction follows the
same subtract-square-reduce order.  As in `rng_round.py`, D is zero-padded
to the 128-lane width for real lowering only; interpret mode — the bitwise
parity harness — skips the pad to keep the fp32 reduction tree intact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Single source of truth for the probe-window length (shared with the
# oracle and the table-insert path in core/search.py).
from repro.kernels.ref import HASH_PROBES


def _search_expand_kernel(nbrs_pref, xrow_ref, *refs,
                          r: int, h: int, probes: int, masked: bool,
                          quantized: bool, filtered: bool):
    """Grid: (Q, R). Step (q, rr) DMAs x[nbrs[q, rr]] (and, in the masked
    variant, the neighbor's validity bit) into scratch row rr; the distance
    + probe evaluation runs once per query on the final row.

    `masked` is a trace-time flag: the static-index path (valid=None)
    compiles WITHOUT the validity operand, scratch, or per-step DMA — the
    dynamic feature costs the hot serving loop nothing unless it is used.
    `quantized` (the precision ladder, DESIGN.md §8) likewise: the int8
    variant carries (1, D) scale/offset operands and dequantizes each
    DMA'd neighbor row as it lands in the fp32 scratch — the same
    elementwise formula as `ref.dequant_rows` (bitwise oracle parity);
    queries stay fp32.  `filtered` (filtered search, DESIGN.md §9) is the
    same idiom again: the neighbor's (1, W) packed label-bitset words ride
    the per-row DMA schedule, the query's (1, W) allowed-bitset words are
    a per-query block, and the intersection test emits the extra `allowed`
    output — route-through semantics, so ids/dists/fresh are UNCHANGED by
    the predicate (the neighbor stays traversable either way).
    """
    del nbrs_pref  # consumed by the index_maps
    it = iter(refs)
    vrow_ref = next(it) if masked else None
    lrow_ref = next(it) if filtered else None
    scale_ref, offset_ref = ((next(it), next(it)) if quantized
                             else (None, None))
    q_ref, nbrs_ref, tab_ref = next(it), next(it), next(it)
    fw_ref = next(it) if filtered else None
    ids_ref, d_ref, fresh_ref = next(it), next(it), next(it)
    alw_ref = next(it) if filtered else None
    vecs_ref = next(it)
    live_ref = next(it) if masked else None
    labw_ref = next(it) if filtered else None
    rr = pl.program_id(1)
    row = xrow_ref[...].astype(jnp.float32)
    if quantized:
        row = row * scale_ref[...] + offset_ref[...]
    vecs_ref[pl.ds(rr, 1), :] = row
    if masked:
        live_ref[pl.ds(rr, 1), :] = vrow_ref[...]
    if filtered:
        labw_ref[pl.ds(rr, 1), :] = lrow_ref[...]

    @pl.when(rr == r - 1)
    def _evaluate():
        vecs = vecs_ref[...]                          # (R, D) f32, VMEM
        qv = q_ref[...].astype(jnp.float32)           # (1, D)
        nbrs = nbrs_ref[...]                          # (1, R) int32
        # wrap-extended table (1, H + PROBES): slot (v % H + l) % H of the
        # H-slot table is slot (v % H) + l here, so each id's probe window
        # is one contiguous O(PROBES) slice — work independent of H
        tab = tab_ref[...]

        diff = vecs - qv                              # (R, D) broadcast
        d = jnp.sum(diff * diff, axis=1).reshape(1, r)

        found = []
        alive = []
        allow = []
        for j in range(r):                            # R is small: unrolled
            v = nbrs[0, j]
            base = jnp.clip(v, 0) % h
            win = jax.lax.dynamic_slice(tab, (jnp.int32(0), base),
                                        (1, probes))
            found.append(jnp.any(win == v))
            if masked:
                alive.append(live_ref[j, 0] != 0)
            if filtered:
                # pure int32 bitwise intersection: bitwise-equal to the
                # oracle's `any(vwords[id] & fwords[q])` on every rung
                allow.append(jnp.any((labw_ref[j, :] & fw_ref[0, :]) != 0))
        found = jnp.stack(found).reshape(1, r)

        # a tombstoned neighbor (valid[v] == 0) is indistinguishable from an
        # empty graph slot: never scored, never returned (ref.py contract)
        ok = nbrs >= 0
        if masked:
            ok = ok & jnp.stack(alive).reshape(1, r)
        d = jnp.where(ok, d, jnp.inf)

        ids_ref[...] = jnp.where(ok, nbrs, -1)
        d_ref[...] = d
        fresh_ref[...] = (ok & ~found).astype(jnp.int32)
        if filtered:
            alw_ref[...] = (ok & jnp.stack(allow).reshape(1, r)
                            ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def search_expand_pallas(
    x: jnp.ndarray,
    queries: jnp.ndarray,
    nbrs: jnp.ndarray,
    table: jnp.ndarray,
    valid: jnp.ndarray | None = None,
    scale: jnp.ndarray | None = None,
    offset: jnp.ndarray | None = None,
    vwords: jnp.ndarray | None = None,
    fwords: jnp.ndarray | None = None,
    *,
    interpret: bool = False,
):
    """Fused expansion step over a (Q, R) neighbor-id batch.

    Args:
      x:       (N, D) dataset (stays in HBM; rows are DMA'd on demand;
               fp32/bf16/int8 storage per the precision ladder).
      queries: (Q, D) query vectors (always fp32 — only the stored dataset
               side rides the ladder).
      nbrs:    (Q, R) int32 neighbor ids of each query's selected vertex,
               -1 = invalid (inactive query or empty graph slot).  R is
               the graph row width: the pool width of a raw GRNND index,
               or the packed degree D of an optimized layout
               (core/layout.py) — the kernel is width-agnostic.
      table:   (Q, H) int32 open-addressed visited table, -1 = empty slot.
      valid:   optional (N,) bool/int32 vertex-validity mask (tombstones,
               core/dynamic.py).  Stays in HBM next to x; each neighbor's
               bit rides the same per-row DMA schedule as its vector, so
               the mask probe adds no extra pass.  None = all live.
      scale/offset: optional (D,) per-dim dequant of the stored x rows,
               fused into the row DMA (None = float storage).
      vwords/fwords: optional filtered-search predicate (core/labels.py):
               (N, W) packed per-vertex label words + (Q, W) per-query
               allowed words.  The neighbor's words ride the same per-row
               DMA schedule as its vector/validity bit; both or neither.

    Returns (ids (Q,R) i32, dists (Q,R) f32, fresh (Q,R) bool) — identical
    to `ref.search_expand_ref`; with the filter operands, a fourth element
    `allowed (Q,R) bool` (route-through: ids/dists/fresh are unchanged).
    """
    qn, r = nbrs.shape
    n, d = x.shape
    h = table.shape[1]
    masked = valid is not None  # trace-time: None is a distinct jit trace
    quantized = scale is not None
    filtered = fwords is not None
    assert filtered == (vwords is not None), \
        "vwords and fwords must be given together"
    nbrs_safe = jnp.clip(nbrs.astype(jnp.int32), 0, n - 1)
    # wrap-extend the table so every (mod H) probe window is contiguous:
    # ext[base + l] == table[(base + l) % H] for base < H, l < PROBES
    # (tiled, not a single concat, so H < PROBES also wraps correctly)
    reps = 1 + -(-HASH_PROBES // h)
    tab_ext = jnp.tile(table.astype(jnp.int32),
                       (1, reps))[:, :h + HASH_PROBES]
    he = h + HASH_PROBES

    # Lane-align D for the real TPU lowering only (see module docstring).
    # scale/offset pad with ZEROS, so padded columns of a quantized x
    # dequant to exactly 0 and contribute nothing to any distance.
    pad_d = 0 if interpret else (-d) % 128
    xp = jnp.pad(x, ((0, 0), (0, pad_d))) if pad_d else x
    qp = jnp.pad(queries, ((0, 0), (0, pad_d))) if pad_d else queries
    dp = d + pad_d

    # the masked variant adds one (1, 1) validity block riding the same
    # nb_ref[q, rr] index map as the x-row gather, plus its (R, 1) scratch
    mask_specs = [pl.BlockSpec((1, 1), lambda q, rr, nb_ref:
                               (nb_ref[q, rr], 0))] if masked else []
    mask_scratch = [pltpu.VMEM((r, 1), jnp.int32)] if masked else []
    mask_ops = ((valid.astype(jnp.int32).reshape(n, 1),) if masked else ())

    # the filtered variant: the neighbor's (1, W) label words ride the same
    # per-row DMA, the query's (1, W) allowed words are a per-query block
    w = vwords.shape[1] if filtered else 0
    lab_specs = [pl.BlockSpec((1, w), lambda q, rr, nb_ref:
                              (nb_ref[q, rr], 0))] if filtered else []
    lab_scratch = [pltpu.VMEM((r, w), jnp.int32)] if filtered else []
    lab_ops = ((vwords.astype(jnp.int32),) if filtered else ())
    fw_specs = [pl.BlockSpec((1, w), lambda q, rr, nb_ref:
                             (q, 0))] if filtered else []
    fw_ops = ((fwords.astype(jnp.int32),) if filtered else ())
    alw_shape = [jax.ShapeDtypeStruct((qn, r), jnp.int32)] if filtered else []
    alw_specs = [pl.BlockSpec((1, r), lambda q, rr, nb_ref:
                              (q, 0))] if filtered else []

    q_ops, q_specs = (), []
    if quantized:
        q_ops = tuple(
            jnp.pad(v.astype(jnp.float32).reshape(1, d), ((0, 0), (0, pad_d)))
            for v in (scale, offset))
        q_specs = [pl.BlockSpec((1, dp), lambda q, rr, nb_ref: (0, 0))] * 2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,               # nbrs_safe lands as index operand
        grid=(qn, r),
        in_specs=[
            pl.BlockSpec((1, dp), lambda q, rr, nb_ref: (nb_ref[q, rr], 0)),
        ] + mask_specs + lab_specs + q_specs + [
            pl.BlockSpec((1, dp), lambda q, rr, nb_ref: (q, 0)),
            pl.BlockSpec((1, r), lambda q, rr, nb_ref: (q, 0)),
            pl.BlockSpec((1, he), lambda q, rr, nb_ref: (q, 0)),
        ] + fw_specs,
        out_specs=[
            pl.BlockSpec((1, r), lambda q, rr, nb_ref: (q, 0)),
            pl.BlockSpec((1, r), lambda q, rr, nb_ref: (q, 0)),
            pl.BlockSpec((1, r), lambda q, rr, nb_ref: (q, 0)),
        ] + alw_specs,
        scratch_shapes=([pltpu.VMEM((r, dp), jnp.float32)] + mask_scratch
                        + lab_scratch),
    )
    out = pl.pallas_call(
        functools.partial(_search_expand_kernel, r=r, h=h,
                          probes=HASH_PROBES, masked=masked,
                          quantized=quantized, filtered=filtered),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, r), jnp.int32),
            jax.ShapeDtypeStruct((qn, r), jnp.float32),
            jax.ShapeDtypeStruct((qn, r), jnp.int32),
        ] + alw_shape,
        interpret=interpret,
    )(nbrs_safe, xp, *mask_ops, *lab_ops, *q_ops, qp,
      nbrs.astype(jnp.int32), tab_ext, *fw_ops)
    if filtered:
        ids, dists, fresh, allowed = out
        return ids, dists, fresh.astype(bool), allowed.astype(bool)
    ids, dists, fresh = out
    return ids, dists, fresh.astype(bool)
