"""Pallas TPU kernel: per-row dedup + top-R-by-distance merge.

This is the TPU-native replacement for the paper's WARP_INSERT (GRNND §3.4,
Alg. 6): the GPU version uses __ballot for set-membership and an atomic
replace-farthest; here a whole row (pool ∪ incoming candidates, width W) is
resident in VMEM/VREGs and processed with pure vector ops:

  * dedup       — O(W^2) equality mask on the VPU, the "ballot" analogue;
  * selection   — R rounds of (min, first-match one-hot, mask-out), the
                  deterministic analogue of replace-farthest-if-closer.

No gathers, no scatter, no atomics: each grid step owns BR independent rows.
The one-hot selection avoids per-row dynamic indexing, which keeps the kernel
fully vectorized on 8x128 vregs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 8


def _topr_merge_kernel(ids_ref, dists_ref, oi_ref, od_ref, *, r: int):
    ids = ids_ref[...]                       # (BR, W) int32
    dists = dists_ref[...].astype(jnp.float32)
    dists = jnp.where(ids < 0, jnp.inf, dists)

    # --- dedup ("ballot"): later slot with an id seen earlier is invalid ---
    same = ids[:, :, None] == ids[:, None, :]            # (BR, W, W)
    w = ids.shape[1]
    earlier = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1) < \
        jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)   # earlier[i, j] = j < i
    dup = jnp.any(same & earlier[None], axis=-1)
    dists = jnp.where(dup, jnp.inf, dists)

    # --- R selection rounds: extract first-min, mask it out ---
    out_ids = []
    out_dists = []
    for _ in range(r):
        minv = jnp.min(dists, axis=-1, keepdims=True)            # (BR, 1)
        is_min = dists == minv
        first = is_min & (jnp.cumsum(is_min.astype(jnp.int32), axis=-1) == 1)
        sel_id = jnp.sum(jnp.where(first, ids, 0), axis=-1)      # (BR,)
        valid = jnp.isfinite(minv[:, 0])
        out_ids.append(jnp.where(valid, sel_id, -1))
        out_dists.append(jnp.where(valid, minv[:, 0], jnp.inf))
        dists = jnp.where(first, jnp.inf, dists)

    oi_ref[...] = jnp.stack(out_ids, axis=-1)
    od_ref[...] = jnp.stack(out_dists, axis=-1)


@functools.partial(jax.jit, static_argnames=("r", "br", "interpret"))
def topr_merge_pallas(
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    r: int,
    *,
    br: int = DEFAULT_BR,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge rows of (ids, dists) (B, W) into the r closest unique entries."""
    b, w = ids.shape
    assert dists.shape == (b, w)

    pad_b = (-b) % br
    pad_w = (-w) % 128 if w > 8 else 0  # lane alignment; tiny widths left as-is
    ids_p = jnp.pad(ids.astype(jnp.int32), ((0, pad_b), (0, pad_w)),
                    constant_values=-1)
    dists_p = jnp.pad(dists.astype(jnp.float32), ((0, pad_b), (0, pad_w)),
                      constant_values=jnp.inf)
    bp, wp = ids_p.shape

    grid = (bp // br,)
    out_ids, out_dists = pl.pallas_call(
        functools.partial(_topr_merge_kernel, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, wp), lambda i: (i, 0)),
            pl.BlockSpec((br, wp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, r), lambda i: (i, 0)),
            pl.BlockSpec((br, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, r), jnp.int32),
            jax.ShapeDtypeStruct((bp, r), jnp.float32),
        ],
        interpret=interpret,
    )(ids_p, dists_p)
    return out_ids[:b], out_dists[:b]
