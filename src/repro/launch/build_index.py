"""Build a GRNND index over a vector dataset and save it.

    PYTHONPATH=src python -m repro.launch.build_index --dataset sift-small \
        --out /tmp/sift.idx.npz [--sharded]

--sharded uses the multi-device build (requires >1 jax device or forced
host devices).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.grnnd_paper import DATASETS
from repro.core import build_graph, sharded_build_graph
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift-small",
                    choices=sorted(DATASETS))
    ap.add_argument("--out", required=True)
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ds = DATASETS[args.dataset]
    preset = {"sift": "sift-like", "deep": "deep-like",
              "gist": "gist-like"}[args.dataset.split("-")[0]]
    x = synthetic.make_preset(jax.random.PRNGKey(args.seed), preset, ds.n)

    t0 = time.perf_counter()
    if args.sharded:
        devs = len(jax.devices())
        mesh = jax.make_mesh((devs,), ("data",))
        pool = sharded_build_graph(mesh, ("data",),
                                   jax.random.PRNGKey(args.seed + 1), x,
                                   ds.build)
    else:
        pool = build_graph(jax.random.PRNGKey(args.seed + 1), x, ds.build)
    pool.ids.block_until_ready()
    dt = time.perf_counter() - t0

    np.savez_compressed(args.out, ids=np.asarray(pool.ids),
                        dists=np.asarray(pool.dists), x=np.asarray(x))
    print(f"built {args.dataset} (n={ds.n}, d={ds.d}) in {dt:.1f}s "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
