import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective statistics.

Usage:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
Each cell writes JSON {mem, cost, collectives, timings} to --out.
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro import compat
from repro.configs import list_archs
from repro.configs.base import SHAPES
from repro.launch import specs as SPEC
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:\[[0-9,]*\]))")
_RESULT_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+\[[0-9,]*\]))\S*\s+([a-z0-9\-]+)")


def _bytes_of_shape(s: str) -> int:
    m = re.match(r"([a-z]+[0-9]+)\[([0-9,]*)\]", s)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for c in _COLLECTIVES:
            # match ` = shape... collective-name(` and fused variants like
            # `all-gather-start`
            if f" {c}(" in stripped or f" {c}-start(" in stripped:
                m = _RESULT_RE.search(stripped)
                total = 0
                if m:
                    tuple_part, single, _ = m.groups()
                    if single:
                        total = _bytes_of_shape(single)
                    elif tuple_part:
                        total = sum(_bytes_of_shape(s) for s in
                                    _SHAPE_RE.findall(tuple_part))
                out[c] += total
                counts[c] += 1
                break
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts,
            "total_bytes": sum(out[c] for c in _COLLECTIVES)}


def _compile_stats(fn, args, mesh) -> dict:
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    return {
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
        },
        "cost": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        "collectives": collective_bytes(hlo),
        "hlo_ops": len(hlo.splitlines()),
    }


def _extrapolate(p1: dict, p2: dict, units: int) -> dict:
    """cost(full) = cost(1 unit) + (units - 1) * [cost(2) - cost(1)]."""
    def lerp(a, b):
        return a + (units - 1) * (b - a)

    out = {"cost": {}, "collectives": {}}
    for k in p1["cost"]:
        out["cost"][k] = lerp(p1["cost"][k], p2["cost"][k])
    for k in p1["collectives"]:
        out["collectives"][k] = lerp(p1["collectives"][k],
                                     p2["collectives"][k])
    return out


def run_cell(arch: str, shape: str, mesh_kind: str,
             cost_probes: bool = True, remat_policy: str = "full") -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    result: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                    "mesh_shape": dict(mesh.shape)}

    ok, reason = SPEC.cell_is_applicable(arch, shape)
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        return result

    # full-size compile: proves sharding coherence + memory fit
    fn, args = SPEC.make_cell(arch, shape, mesh, remat_policy=remat_policy)
    full = _compile_stats(fn, args, mesh)
    result.update({"status": "ok", **full})
    result["cost_raw_scanned"] = full["cost"]  # body-once numbers, for ref

    # cost probes: truncated + unrolled k=1, k=2 -> linear extrapolation
    if cost_probes and arch != "grnnd-ann":
        from repro.configs import get_arch
        from repro.configs.base import n_pattern_units
        units = n_pattern_units(get_arch(arch))
        if units >= 2:
            f1, a1 = SPEC.make_cell(arch, shape, mesh, cost_probe=1,
                                    remat_policy=remat_policy)
            p1 = _compile_stats(f1, a1, mesh)
            f2, a2 = SPEC.make_cell(arch, shape, mesh, cost_probe=2,
                                    remat_policy=remat_policy)
            p2 = _compile_stats(f2, a2, mesh)
            ex = _extrapolate(p1, p2, units)
            result["cost"] = ex["cost"]
            result["collectives"] = ex["collectives"]
            result["probe_compile_s"] = [p1["compile_s"], p2["compile_s"]]
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-grnnd", action="store_true")
    ap.add_argument("--remat-policy", type=str, default="full")
    ap.add_argument("--out", type=str, default="results/dryrun")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s) for a in list_archs() for s in SHAPES]
        if args.include_grnnd:
            cells += [("grnnd-ann", s) for s in SPEC.GRNND_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch}__{shape}__{mk}"
            fpath = outdir / f"{tag}.json"
            if fpath.exists():
                prev = json.loads(fpath.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[cached] {tag}: {prev['status']}")
                    n_ok += prev["status"] == "ok"
                    n_skip += prev["status"] == "skipped"
                    continue
            try:
                res = run_cell(arch, shape, mk,
                               remat_policy=args.remat_policy)
            except Exception as e:  # record the failure, keep sweeping
                res = {"arch": arch, "shape": shape, "mesh": mk,
                       "status": "failed", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            fpath.write_text(json.dumps(res, indent=2))
            st = res["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_fail += st == "failed"
            extra = ""
            if st == "ok":
                gb = res["memory"]["argument_size_bytes"] / 2**30
                extra = (f" compile={res['compile_s']}s arg={gb:.2f}GiB "
                         f"coll={res['collectives']['total_bytes']/2**30:.2f}GiB")
            elif st == "failed":
                extra = " " + res["error"][:160]
            print(f"[{st}] {tag}{extra}", flush=True)

    print(f"\nDONE ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
