"""Production mesh construction.

NOTE: importing this module never touches jax device state; meshes are built
inside functions only (the dry-run forces 512 host devices *before* any jax
import — see dryrun.py).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (single pod, 256 chips) or 2x16x16 (two pods, 512 chips).

    REPRO_MESH_OVERRIDE="4,4" (or "2,4,4" for multi-pod) substitutes a
    smaller mesh — used by the test suite to exercise the dry-run machinery
    on a handful of forced host devices.
    """
    import os
    override = os.environ.get("REPRO_MESH_OVERRIDE")
    if override:
        shape = tuple(int(v) for v in override.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "run under dryrun.py (XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512) or on real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for tests on a handful of forced host devices."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW_PER_LINK = 50e9       # bytes/s per link (~50 GB/s)
