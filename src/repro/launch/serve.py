"""Serve batched ANN queries against a saved GRNND index.

    PYTHONPATH=src python -m repro.launch.serve --index /tmp/sift.idx.npz \
        [--batches 8] [--ef 48] [--backend pallas] [--visited hashed] \
        [--visited-cap 512] [--shards 4]

`--backend` selects the kernel path of the fused expansion step
(`kernels/search_expand.py`; off-TPU "pallas" degrades to interpret mode).
`--visited hashed` swaps the dense (Q, N) visited bitmask for the O(Q·H)
per-query open-addressed table — the memory-flat serving configuration
(DESIGN.md §6).  `--shards K` shards the query batch over the first K
devices via `core.distributed.distributed_search` (bitwise-identical to
the single-device search; on a CPU box force host devices first with
XLA_FLAGS=--xla_force_host_platform_device_count=K).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brute_force_knn, recall_at_k
from repro.core.distributed import distributed_search
from repro.core.search import medoid, search
from repro.data import synthetic
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--index", required=True)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ef", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for the search "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--visited", default="dense",
                    choices=["dense", "hashed"],
                    help="visited-set representation")
    ap.add_argument("--visited-cap", type=int, default=None,
                    help="hashed-table slots per query "
                         "(default: core.search.default_visited_cap(ef))")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard query batches over this many devices "
                         "(0 = single-device search)")
    args = ap.parse_args()

    if args.visited_cap is not None and args.visited != "hashed":
        ap.error("--visited-cap only applies with --visited hashed "
                 "(dense mode would silently ignore it)")
    if args.shards > len(jax.devices()):
        ap.error(f"--shards {args.shards} exceeds the {len(jax.devices())} "
                 "available device(s); on a CPU box force host devices with "
                 f"XLA_FLAGS=--xla_force_host_platform_device_count={args.shards}")

    if args.backend is not None:
        ops.set_backend(args.backend)

    blob = np.load(args.index)
    x = jnp.asarray(blob["x"])
    ids = jnp.asarray(blob["ids"])
    entry = medoid(x)

    mesh = None
    if args.shards > 0:
        mesh = jax.make_mesh((args.shards,), ("data",),
                             devices=jax.devices()[:args.shards])
        # replicate the index across the mesh ONCE; the per-batch
        # device_put inside distributed_search then no-ops on x/ids
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        x = jax.device_put(x, rep)
        ids = jax.device_put(ids, rep)
        entry = jax.device_put(entry, rep)

    def run_batch(q):
        kw = dict(k=args.k, ef=args.ef, entry=entry, visited=args.visited,
                  visited_cap=args.visited_cap)
        if mesh is None:
            return search(x, ids, q, **kw)
        return distributed_search(mesh, ("data",), x, ids, q, **kw)

    lat, recs = [], []
    for b in range(args.batches + 1):
        q = synthetic.queries_from(jax.random.PRNGKey(100 + b), x,
                                   args.batch_size)
        t0 = time.perf_counter()
        res = run_batch(q)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        if b == 0:
            continue  # compile batch
        lat.append(dt)
        gt = brute_force_knn(x, q, args.k)
        recs.append(recall_at_k(res.ids, gt))

    qps = args.batch_size / (sum(lat) / len(lat))
    print(f"qps={qps:.0f}  p50={sorted(lat)[len(lat)//2]*1e3:.1f}ms  "
          f"recall@{args.k}={sum(recs)/len(recs):.3f}  "
          f"backend={ops.effective_backend()}  visited={args.visited}  "
          f"shards={max(args.shards, 1)}")


if __name__ == "__main__":
    main()
