"""Serve batched ANN queries against a saved GRNND index.

    PYTHONPATH=src python -m repro.launch.serve --index /tmp/sift.idx.npz \
        [--batches 8] [--ef 48] [--backend pallas] [--visited hashed] \
        [--visited-cap 512] [--shards 4] [--precision int8] \
        [--mutable --churn 64] [--filter-labels 100 --selectivity 0.1] \
        [--engine --requests 256 --offered-qps 500 --mix-k 5,10]

`--backend` selects the kernel path of the fused expansion step
(`kernels/search_expand.py`; off-TPU "pallas" degrades to interpret mode).
`--visited hashed` swaps the dense (Q, N) visited bitmask for the O(Q·H)
per-query open-addressed table — the memory-flat serving configuration
(DESIGN.md §6).  `--shards K` shards the query batch over the first K
devices via `core.distributed.distributed_search` (bitwise-identical to
the single-device search; on a CPU box force host devices first with
XLA_FLAGS=--xla_force_host_platform_device_count=K).

`--corpus-shards S` shards the CORPUS instead (core/corpus_shard.py,
DESIGN.md §11): each shard owns 1/S of the vectors, graph rows, labels,
and rescore tier — the layout that breaks the single-device memory
ceiling on N.  Results are bitwise-identical to the replicated search for
any S (the tests/test_corpus_shard.py invariance tier).  With at least S
devices the shards map one-per-device over a mesh; with fewer, the
in-process reference executor runs the identical math (useful for
validation — the memory win needs real devices).  Mutually exclusive
with `--shards` (one sharding axis per process; compose them via a 2-D
mesh in a custom launcher) and `--mutable`.

`--precision {fp32,bf16,int8}` selects the traversal-tier storage (the
precision ladder, DESIGN.md §8): bf16 halves and int8 quarters the
bytes/vector the bandwidth-bound expansion kernel reads.  At int8 the
final ef candidates are re-ranked against the fp32 tier (exact
distances) unless `--no-rescore` is given; the printed `bpv=` column is
the traversal-tier bytes/vector.

`--tier {device,host}` places that fp32 rescore tier (DESIGN.md §13):
`host` pins it on the CPU backend — device memory holds the quantized
traversal tier + graph only — and the re-rank gathers the final ef rows
per query across the host boundary.  Results are bitwise-identical to
`--tier device` (tests/test_tiered.py); requires a quantized
`--precision` with rescoring on.  Composes with every serving mode:
`--shards` (the tier never replicates onto the mesh), `--corpus-shards`
(no per-shard rescore slice exists), `--engine`, and `--mutable`
(inserts write the host tier in place).

`--filter-labels L` turns on FILTERED serving (DESIGN.md §9): every vertex
gets a synthetic label uniform in [0, L) (deterministic seed), and each
query carries a random allowed-label predicate of ~`--selectivity`·L
labels.  The search routes through filtered-out vertices but returns only
predicate-passing ids (a hard invariant, printed as `pred_ok=`; recall is
scored against brute force over each query's ALLOWED subset).  `ef` is
automatically raised to the over-fetch floor ~4·k/selectivity (§9.3) —
the printed `ef=` field shows the effective value.  Composes with
`--shards` (predicates shard with the queries) and `--mutable` (labels
ride through insert/delete/compact).

`--engine` replaces the fixed-batch loop with the continuous-batching
engine (`serve/ann_engine.py`, DESIGN.md §12): a synthetic open-loop
trace of small heterogeneous requests — k/ef drawn per request from
`--mix-k`/`--mix-ef`, every other request filtered under
`--filter-labels`, insert/delete churn every `--churn-every` queries
under `--mutable` — is coalesced into jit-bucketed `(Q, ef, filtered?)`
batches.  Results are bitwise-identical to the direct path
(tests/test_ann_engine.py); the report adds p50/p99 per-request latency,
achieved vs offered QPS, batch occupancy, and the compiled-bucket count.
Composes with `--precision`, `--optimize-layout`, `--corpus-shards`, and
`--mutable` (but not `--shards`: the engine shapes its own batches).

`--mutable` wraps the loaded index in a `core.dynamic.DynamicIndex` and
interleaves mutation requests with the query batches: every batch first
INSERTS `--churn` fresh vectors and DELETES the `--churn` oldest live
labels (a sliding-window corpus, the workload a static build cannot
serve), then runs the search batch.  Recall is scored against exact
brute force over the LIVE corpus, and mutation latency is reported next
to query throughput.  Compaction auto-triggers on the tombstone
threshold (DESIGN.md §7).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brute_force_knn, recall_at_k, vecstore
from repro.core import labels as lab
from repro.core import layout
from repro.core.distributed import distributed_search
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.pools import Pool
from repro.core.search import medoid, overfetch_ef, search
from repro.data import synthetic
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--index", required=True)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ef", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for the search "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--visited", default="dense",
                    choices=["dense", "hashed"],
                    help="visited-set representation")
    ap.add_argument("--visited-cap", type=int, default=None,
                    help="hashed-table slots per query "
                         "(default: core.search.default_visited_cap(ef))")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard query batches over this many devices "
                         "(0 = single-device search)")
    ap.add_argument("--corpus-shards", type=int, default=0,
                    help="shard the CORPUS over this many partitions "
                         "(core/corpus_shard.py; 0 = replicated).  One "
                         "shard per device when enough devices exist, "
                         "else the bitwise-identical in-process reference")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="traversal-tier vector storage (DESIGN.md §8); "
                         "int8 rescores the final candidates against the "
                         "fp32 tier unless --no-rescore")
    ap.add_argument("--no-rescore", action="store_true",
                    help="skip the fp32 rescoring pass (quantized "
                         "precisions only; shows the raw traversal-space "
                         "recall)")
    ap.add_argument("--tier", default="device",
                    choices=list(vecstore.PLACEMENTS),
                    help="fp32 rescore-tier placement (DESIGN.md §13): "
                         "'host' pins the rescore tier on the CPU backend "
                         "— device memory holds the quantized traversal "
                         "tier + graph only, and the re-rank gathers the "
                         "final ef rows per query across the boundary "
                         "(bitwise-identical results; needs a quantized "
                         "--precision with rescoring on)")
    ap.add_argument("--mutable", action="store_true",
                    help="serve through a DynamicIndex with per-batch "
                         "insert/delete churn (see module docstring)")
    ap.add_argument("--churn", type=int, default=None,
                    help="vectors inserted AND deleted per batch "
                         "(only with --mutable; default 64)")
    ap.add_argument("--refine-rounds", type=int, default=None,
                    help="localized propagation rounds per insert batch "
                         "(only with --mutable; default 2)")
    ap.add_argument("--optimize-layout", default=None,
                    choices=list(layout.ORDERS),
                    help="run the post-build layout pass (core/layout.py, "
                         "DESIGN.md §10) before serving: packed fixed-"
                         "degree adjacency + the chosen vertex renumbering; "
                         "results are bitwise-identical, ids stay in the "
                         "original numbering.  With --mutable, slots are "
                         "renumbered at startup and after every compact()")
    ap.add_argument("--filter-labels", type=int, default=0,
                    help="filtered serving: synthetic per-vertex labels in "
                         "[0, L); each query gets a random allowed-label "
                         "predicate (0 = unfiltered)")
    ap.add_argument("--selectivity", type=float, default=None,
                    help="fraction of the label space each query predicate "
                         "allows (only with --filter-labels; default 0.1)")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine serving (serve/"
                         "ann_engine.py, DESIGN.md §12): a trace-driven "
                         "stream of small requests with mixed k/ef/filter "
                         "(plus insert/delete churn under --mutable) is "
                         "coalesced into jit-bucketed batches; reports "
                         "p50/p99 latency, QPS, occupancy, bucket count")
    ap.add_argument("--offered-qps", type=float, default=None,
                    help="trace arrival rate (only with --engine; default: "
                         "auto-calibrate to the measured batch capacity)")
    ap.add_argument("--requests", type=int, default=256,
                    help="trace length in queries (only with --engine)")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="trace RNG seed (only with --engine)")
    ap.add_argument("--mix-k", default="5,10",
                    help="comma-separated k menu the trace draws from "
                         "(only with --engine)")
    ap.add_argument("--mix-ef", default=None,
                    help="comma-separated ef menu the trace draws from "
                         "(only with --engine; default: just --ef)")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="engine batch-size ceiling (only with --engine)")
    ap.add_argument("--quantum", type=int, default=4,
                    help="query batches per mutation drain when both "
                         "queues are backed up (only with --engine)")
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="admission-control queue bound (only with "
                         "--engine; excess requests are shed and counted)")
    ap.add_argument("--churn-every", type=int, default=32,
                    help="queries between churn events in the trace (only "
                         "with --engine --mutable)")
    args = ap.parse_args()

    if args.visited_cap is not None and args.visited != "hashed":
        ap.error("--visited-cap only applies with --visited hashed "
                 "(dense mode would silently ignore it)")
    if args.shards > len(jax.devices()):
        ap.error(f"--shards {args.shards} exceeds the {len(jax.devices())} "
                 "available device(s); on a CPU box force host devices with "
                 f"XLA_FLAGS=--xla_force_host_platform_device_count={args.shards}")
    if args.shards > 0 and args.mutable:
        ap.error("--mutable currently serves single-device (the mutation "
                 "path is not query-sharded); drop --shards")
    if args.corpus_shards > 0 and args.shards > 0:
        ap.error("--corpus-shards and --shards pick one sharding axis per "
                 "process; compose them via a 2-D mesh in a custom launcher")
    if args.corpus_shards > 0 and args.mutable:
        ap.error("--mutable serves the replicated layout; use "
                 "DynamicIndex.corpus_search for corpus-sharded mutation "
                 "serving")
    if not args.mutable and (args.churn is not None
                             or args.refine_rounds is not None):
        ap.error("--churn/--refine-rounds only apply with --mutable")
    if args.no_rescore and args.precision == "fp32":
        ap.error("--no-rescore only applies with --precision bf16/int8 "
                 "(fp32 traversal is already exact)")
    if args.tier == "host" and args.precision == "fp32":
        ap.error("--tier host places the fp32 RESCORE tier; at --precision "
                 "fp32 the fp32 buffer IS the traversal tier and must stay "
                 "device-resident")
    if args.tier == "host" and args.no_rescore:
        ap.error("--tier host without a rescore pass places nothing; drop "
                 "--no-rescore")
    if args.selectivity is not None and not args.filter_labels:
        ap.error("--selectivity only applies with --filter-labels")
    if args.filter_labels and not (args.selectivity is None
                                   or 0 < args.selectivity <= 1):
        ap.error("--selectivity must be in (0, 1]")
    if args.engine and args.shards > 0:
        ap.error("--engine shapes its own batches; query-sharding a "
                 "dynamic batch needs a custom worker (drop --shards)")
    if not args.engine and (args.offered_qps is not None
                            or args.mix_ef is not None):
        ap.error("--offered-qps/--mix-ef only apply with --engine")
    if args.engine and args.mutable and args.corpus_shards > 0:
        ap.error("--engine --mutable serves the replicated layout")

    if args.backend is not None:
        ops.set_backend(args.backend)

    blob = np.load(args.index)
    x = jnp.asarray(blob["x"])
    ids = jnp.asarray(blob["ids"])

    if args.engine:
        serve_engine(args, x, blob, ids)
        return
    if args.mutable:
        serve_mutable(args, x, jnp.asarray(blob["dists"]), ids)
        return

    (xt, ids, entry, rescore, bpv, lstore, sel, ef, words, ids_map,
     cs_idx, cs_mesh) = _static_setup(args, x, ids)

    mesh = None
    if args.shards > 0:
        mesh = jax.make_mesh((args.shards,), ("data",),
                             devices=jax.devices()[:args.shards])
        # replicate the index across the mesh ONCE; the per-batch
        # device_put inside distributed_search then no-ops on x/ids
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        xt = jax.tree.map(lambda a: jax.device_put(a, rep), xt)
        ids = jax.device_put(ids, rep)
        entry = jax.device_put(entry, rep)
        if rescore is not None and not vecstore.is_host(rescore):
            rescore = jax.device_put(rescore, rep)  # host tier stays put
        if ids_map is not None:
            ids_map = jax.device_put(ids_map, rep)

    def run_batch(q, fwords):
        if cs_idx is not None:
            return cs_idx.search(
                q, k=args.k, ef=ef, visited=args.visited,
                visited_cap=args.visited_cap, filter=fwords,
                mesh=cs_mesh)
        kw = dict(k=args.k, ef=ef, entry=entry, visited=args.visited,
                  visited_cap=args.visited_cap, rescore=rescore,
                  ids_map=ids_map)
        if lstore is not None:
            kw.update(labels=words, filter=fwords)
        if mesh is None:
            return search(xt, ids, q, **kw)
        return distributed_search(mesh, ("data",), xt, ids, q, **kw)

    lat, recs, preds = [], [], []
    for b in range(args.batches + 1):
        kb = jax.random.PRNGKey(100 + b)
        q = synthetic.queries_from(kb, x, args.batch_size)
        fw = (lab.random_query_filters(jax.random.fold_in(kb, 7),
                                       args.batch_size, args.filter_labels,
                                       sel)
              if lstore is not None else None)
        t0 = time.perf_counter()
        res = run_batch(q, fw)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        if b == 0:
            continue  # compile batch
        lat.append(dt)
        if lstore is None:
            gt = brute_force_knn(x, q, args.k)
            recs.append(recall_at_k(res.ids, gt))
        else:
            # recall against brute force over each query's ALLOWED subset,
            # plus the hard invariant: every returned id passes its predicate
            gt = lab.filtered_brute_force(x, q, fw, lstore.words, args.k)
            recs.append(lab.filtered_recall_at_k(res.ids, gt))
            preds.append(lab.predicate_fraction(res.ids, fw, lstore.words))

    qps = args.batch_size / (sum(lat) / len(lat))
    extra = ""
    if lstore is not None:
        extra = (f"filtered=1  selectivity={sel:g}  "
                 f"pred_ok={sum(preds)/len(preds):.3f}  ef={ef}  ")
    print(f"qps={qps:.0f}  p50={sorted(lat)[len(lat)//2]*1e3:.1f}ms  "
          f"recall@{args.k}={sum(recs)/len(recs):.3f}  {extra}"
          f"backend={ops.effective_backend()}  visited={args.visited}  "
          f"precision={args.precision}  bpv={bpv:.0f}  "
          f"rescore={int(rescore is not None)}  "
          f"tier={args.tier}  "
          f"opt_layout={args.optimize_layout or 'none'}  "
          f"shards={max(args.shards, 1)}  "
          f"corpus_shards={max(args.corpus_shards, 1)}")


def serve_engine(args, x, blob, ids):
    """--engine: continuous-batching serving (serve/ann_engine.py, §12).

    A synthetic open-loop trace (Poisson arrivals, per-request k/ef drawn
    from --mix-k/--mix-ef, with --filter-labels every other request carries
    a predicate, with --mutable a churn pair lands every --churn-every
    queries) is replayed against the engine.  A closed-loop warm-up replay
    first compiles the jit buckets and measures capacity (the default
    --offered-qps is 70% of it); the measured replay then reports
    p50/p99 latency, QPS, occupancy, and the bucket-trace count.
    """
    import dataclasses

    from repro.serve import ann_engine as AE

    k_choices = [int(s) for s in args.mix_k.split(",") if s.strip()]
    ef_choices = ([int(s) for s in args.mix_ef.split(",") if s.strip()]
                  if args.mix_ef else [args.ef])
    cfg = AE.EngineConfig(max_pending=args.max_pending,
                          max_batch=args.max_batch,
                          query_quantum=args.quantum,
                          ef_menu=tuple(sorted(set(ef_choices))))
    if max(k_choices) > min(cfg.k_cap, min(ef_choices)):
        raise SystemExit(f"--mix-k max {max(k_choices)} exceeds "
                         f"min(k_cap={cfg.k_cap}, ef={min(ef_choices)})")

    kq = jax.random.PRNGKey(9000 + args.trace_seed)
    q = np.asarray(synthetic.queries_from(kq, x, args.requests))

    # build the worker for the requested serving configuration
    mut_every, churn_vecs, churn_labs = 0, None, None
    if args.mutable:
        lstore, sel, _ = _filter_setup(args, x.shape[0])
        rounds = args.refine_rounds if args.refine_rounds is not None else 2
        idx = DynamicIndex(x, Pool(ids, jnp.asarray(blob["dists"])),
                           DynamicConfig(refine_rounds=rounds,
                                         precision=args.precision,
                                         tier=args.tier,
                                         layout=args.optimize_layout),
                           vertex_labels=(None if lstore is None
                                          else lstore.labels),
                           n_labels=(args.filter_labels
                                     if lstore is not None else None))
        worker = AE.DynamicWorker(idx, visited=args.visited,
                                  visited_cap=args.visited_cap)
        churn = args.churn if args.churn is not None else 16
        mut_every = args.churn_every
        n_churn = max(1, args.requests // max(mut_every, 1))
        churn_vecs = [np.asarray(synthetic.queries_from(
            jax.random.fold_in(kq, 100 + i), x, churn, noise=0.1))
            for i in range(n_churn)]
        if lstore is not None:
            churn_labs = [np.asarray(jax.random.randint(
                jax.random.fold_in(kq, 200 + i), (churn,), 0,
                args.filter_labels), np.int32) for i in range(n_churn)]
    else:
        (xt, gids, entry, rescore, _bpv, lstore, sel, _ef, words, ids_map,
         cs_idx, cs_mesh) = _static_setup(args, x, ids)
        if cs_idx is not None:
            worker = AE.ShardedWorker(cs_idx, mesh=cs_mesh,
                                      visited=args.visited,
                                      visited_cap=args.visited_cap)
        else:
            worker = AE.StaticWorker(xt, gids, entry=entry,
                                     visited=args.visited,
                                     visited_cap=args.visited_cap,
                                     rescore=rescore, labels=words,
                                     ids_map=ids_map)

    # every other request filtered (a mixed-predicate stream), the rest plain
    fwords = None
    if lstore is not None:
        fw = np.asarray(lab.random_query_filters(
            jax.random.fold_in(kq, 7), args.requests, args.filter_labels,
            sel))
        fwords = [fw[i] if i % 2 == 0 else None
                  for i in range(args.requests)]

    def make_trace(offered):
        rng = np.random.default_rng(args.trace_seed)
        return AE.synth_trace(rng, q, offered_qps=offered,
                              k_choices=k_choices, ef_choices=ef_choices,
                              fwords=fwords, mutation_every=mut_every,
                              churn_vectors=churn_vecs,
                              churn_labels=churn_labs)

    eng = AE.AnnEngine(worker, cfg)

    # closed-loop warm-up: everything arrives at t~0, so the big buckets
    # compile here and the drain rate measures the engine's capacity
    warm_rids = AE.replay(eng, [dataclasses.replace(ev, t=0.0)
                                for ev in make_trace(1.0)])
    for rid in warm_rids.values():
        eng.take_result(rid)
    capacity = max(eng.stats().qps, 1.0)
    eng.reset_stats()

    offered = (args.offered_qps if args.offered_qps is not None
               else 0.7 * capacity)
    trace = make_trace(offered)
    rids = AE.replay(eng, trace)
    s = eng.stats()

    extra = ""
    if args.mutable:
        extra = (f"mutations/s={s.mutations_per_sec:.0f}  "
                 f"live={idx.n_live}  ")
    else:
        # recall + the filtered hard invariant, per admitted request
        row_of = {ti: j for j, ti in enumerate(
            i for i, ev in enumerate(trace) if ev.kind == "query")}
        kmax = max(k_choices)
        gt_plain = np.asarray(brute_force_knn(x, jnp.asarray(q), kmax))
        recs, preds = [], []
        for ti, rid in rids.items():
            ev, res = trace[ti], eng.take_result(rid)
            if ev.fwords is None:
                recs.append(recall_at_k(res.ids[None],
                                        gt_plain[row_of[ti], :ev.k][None]))
            else:
                fwr = jnp.asarray(ev.fwords)[None]
                gt = lab.filtered_brute_force(x, jnp.asarray(q[row_of[ti]])[None],
                                              fwr, lstore.words, ev.k)
                recs.append(lab.filtered_recall_at_k(res.ids[None], gt))
                preds.append(lab.predicate_fraction(
                    jnp.asarray(res.ids)[None], fwr, lstore.words))
        extra = f"recall={sum(recs) / max(len(recs), 1):.3f}  "
        if preds:
            extra += f"pred_ok={sum(preds) / len(preds):.3f}  "

    print(f"engine=1  qps={s.qps:.0f}  offered={offered:.0f}  "
          f"p50={s.p50_ms:.1f}ms  p99={s.p99_ms:.1f}ms  "
          f"occupancy={s.mean_occupancy:.2f}  buckets={s.n_buckets}  "
          f"completed={s.n_completed}  rejected={s.n_rejected}  {extra}"
          f"backend={ops.effective_backend()}  visited={args.visited}  "
          f"precision={args.precision}  tier={args.tier}  "
          f"mutable={int(args.mutable)}  "
          f"corpus_shards={max(args.corpus_shards, 1)}")


def _static_setup(args, x, ids):
    """The frozen-index serving operands, shared by the fixed-batch path
    and the engine's StaticWorker/ShardedWorker: precision tier (§8),
    filtered-serving labels (§9), optional layout pass (§10), optional
    corpus sharding (§11)."""
    # the precision ladder (DESIGN.md §8): traversal reads the compact
    # tier; the fp32 array stays around only as the rescoring tier
    store = vecstore.encode(x, args.precision)
    xt = x if args.precision == "fp32" else store
    rescore = x if (args.precision != "fp32" and not args.no_rescore) else None
    bpv = store.bytes_per_vector()
    entry = medoid(xt)

    lstore, sel, ef = _filter_setup(args, x.shape[0])

    words = None if lstore is None else lstore.words
    ids_map = None
    if args.optimize_layout:
        # the post-build layout pass (DESIGN.md §10): every index-side
        # operand is permuted together and `ids_map` restores original
        # numbering on the way out, so gt scoring below is untouched
        opt = layout.optimize(xt, ids, order=args.optimize_layout,
                              rescore=rescore, labels=words, entry=entry)
        xt, ids, entry, rescore = opt.x, opt.graph_ids, opt.entry, opt.rescore
        ids_map = opt.inv
        if words is not None:
            words = opt.vwords

    cs_idx = cs_mesh = None
    if args.corpus_shards > 0:
        from repro.core import corpus_shard as CS
        # partition AFTER the optional layout pass (the §11 composition
        # contract: shards slice the permuted rows, ids_map restores the
        # caller's numbering owner-side).  --tier host keeps the rescore
        # tier off the shards entirely (§13).
        cs_idx = CS.shard(xt, ids, args.corpus_shards, rescore=rescore,
                          labels=words, ids_map=ids_map, entry=entry,
                          tier=args.tier)
        if args.corpus_shards <= len(jax.devices()):
            cs_mesh = jax.make_mesh(
                (args.corpus_shards,), ("data",),
                devices=jax.devices()[:args.corpus_shards])
    elif args.tier == "host" and rescore is not None:
        # host-cold placement (§13): wrap AFTER the layout pass so the
        # pinned tier holds the permuted rows the internal ids index
        rescore = vecstore.HostTier(rescore)
    return (xt, ids, entry, rescore, bpv, lstore, sel, ef, words, ids_map,
            cs_idx, cs_mesh)


def _filter_setup(args, n: int):
    """(LabelStore | None, selectivity, effective ef) for filtered serving.

    Labels are synthetic and deterministic (the saved index carries no
    attributes); the effective ef applies the §9.3 over-fetch policy
    (`core.search.overfetch_ef` — the same single source fig12
    benchmarks and validates) so ~k allowed survivors exist even at low
    selectivity.
    """
    if not args.filter_labels:
        return None, None, args.ef
    vlab = jax.random.randint(jax.random.PRNGKey(1234), (n,), 0,
                              args.filter_labels)
    lstore = lab.encode_labels(vlab, args.filter_labels)
    sel = args.selectivity if args.selectivity is not None else 0.1
    return lstore, sel, overfetch_ef(n, args.k, sel, ef=args.ef)


def serve_mutable(args, x, dists, ids):
    """--mutable: per-batch insert/delete churn through a DynamicIndex.

    Only batch 0 is excluded as the compile batch: a mid-run capacity
    doubling or auto-compaction changes buffer shapes and retraces the
    jits, and those seconds land in the reported latencies — faithful for
    an ops view of steady-state serving (stalls included), but use
    benchmarks/fig10_churn.py (which warms an exact replay) for clean
    mutation-throughput numbers.
    """
    rounds = args.refine_rounds if args.refine_rounds is not None else 2
    lstore, sel, ef = _filter_setup(args, x.shape[0])
    nl = args.filter_labels
    idx = DynamicIndex(x, Pool(ids, dists),
                       DynamicConfig(refine_rounds=rounds,
                                     precision=args.precision,
                                     tier=args.tier,
                                     layout=args.optimize_layout),
                       vertex_labels=(None if lstore is None
                                      else lstore.labels),
                       n_labels=nl if lstore is not None else None)
    churn = args.churn if args.churn is not None else 64
    mut_lat, lat, recs, preds = [], [], [], []
    for b in range(args.batches + 1):
        kb = jax.random.PRNGKey(100 + b)
        t0 = time.perf_counter()
        if churn > 0:
            idx.insert(synthetic.queries_from(kb, x, churn, noise=0.1),
                       vertex_labels=(None if lstore is None else np.asarray(
                           jax.random.randint(jax.random.fold_in(kb, 3),
                                              (churn,), 0, nl), np.int32)))
            live = idx.labels[:idx.size][np.asarray(idx.valid[:idx.size])]
            # oldest live = smallest labels: a sliding-window corpus.  Sort
            # first — under a layout permutation slot order is NOT label
            # order (core/layout.py)
            idx.delete(np.sort(live)[:churn])
        t_mut = time.perf_counter() - t0

        q = synthetic.queries_from(jax.random.fold_in(kb, 1), x,
                                   args.batch_size)
        fw = (lab.random_query_filters(jax.random.fold_in(kb, 7),
                                       args.batch_size, nl, sel)
              if lstore is not None else None)
        t0 = time.perf_counter()
        res = idx.search(q, k=args.k, ef=ef, visited=args.visited,
                         visited_cap=args.visited_cap,
                         rescore=False if args.no_rescore else None,
                         filter=fw)
        res.dists.block_until_ready()
        dt = time.perf_counter() - t0
        if b == 0:
            continue  # compile batch
        mut_lat.append(t_mut)
        lat.append(dt)
        gt = idx.exact_knn(q, args.k, filter=fw)
        if lstore is None:
            recs.append(recall_at_k(res.ids, gt))
        else:
            recs.append(lab.filtered_recall_at_k(res.ids, gt))
            # the hard invariant, mapped back from label space: every
            # returned external label's slot must pass its predicate
            # (the canonical check, lab.predicate_fraction, runs on slots)
            r_ids = np.asarray(res.ids)
            table = idx.labels[:idx.size]
            # argsort-backed lookup: identical to the plain binary search
            # without a layout permutation, correct with one
            sorter = np.argsort(table, kind="stable")
            pos = np.clip(np.searchsorted(table, np.clip(r_ids, 0, None),
                                          sorter=sorter),
                          0, idx.size - 1)
            slots = np.where(r_ids >= 0, sorter[pos], -1)
            preds.append(lab.predicate_fraction(jnp.asarray(slots), fw,
                                                idx.label_words()))

    qps = args.batch_size / (sum(lat) / len(lat))
    mut_per_s = 2 * churn / (sum(mut_lat) / len(mut_lat)) if churn else 0.0
    extra = ""
    if lstore is not None:
        extra = (f"filtered=1  selectivity={sel:g}  "
                 f"pred_ok={sum(preds)/len(preds):.3f}  ef={ef}  ")
    print(f"qps={qps:.0f}  p50={sorted(lat)[len(lat)//2]*1e3:.1f}ms  "
          f"recall@{args.k}={sum(recs)/len(recs):.3f}  {extra}"
          f"mutations/s={mut_per_s:.0f}  churn={churn}  "
          f"live={idx.n_live}  tomb={idx.tombstone_fraction:.2f}  "
          f"rounds={idx.rounds_run}  "
          f"backend={ops.effective_backend()}  visited={args.visited}  "
          f"precision={args.precision}  tier={args.tier}  "
          f"opt_layout={args.optimize_layout or 'none'}  mutable=1  "
          f"corpus_shards=1")


if __name__ == "__main__":
    main()
