"""Serve batched ANN queries against a saved GRNND index.

    PYTHONPATH=src python -m repro.launch.serve --index /tmp/sift.idx.npz \
        [--batches 8] [--ef 48] [--backend pallas] [--visited hashed] \
        [--visited-cap 512] [--shards 4] [--precision int8] \
        [--mutable --churn 64]

`--backend` selects the kernel path of the fused expansion step
(`kernels/search_expand.py`; off-TPU "pallas" degrades to interpret mode).
`--visited hashed` swaps the dense (Q, N) visited bitmask for the O(Q·H)
per-query open-addressed table — the memory-flat serving configuration
(DESIGN.md §6).  `--shards K` shards the query batch over the first K
devices via `core.distributed.distributed_search` (bitwise-identical to
the single-device search; on a CPU box force host devices first with
XLA_FLAGS=--xla_force_host_platform_device_count=K).

`--precision {fp32,bf16,int8}` selects the traversal-tier storage (the
precision ladder, DESIGN.md §8): bf16 halves and int8 quarters the
bytes/vector the bandwidth-bound expansion kernel reads.  At int8 the
final ef candidates are re-ranked against the fp32 tier (exact
distances) unless `--no-rescore` is given; the printed `bpv=` column is
the traversal-tier bytes/vector.

`--mutable` wraps the loaded index in a `core.dynamic.DynamicIndex` and
interleaves mutation requests with the query batches: every batch first
INSERTS `--churn` fresh vectors and DELETES the `--churn` oldest live
labels (a sliding-window corpus, the workload a static build cannot
serve), then runs the search batch.  Recall is scored against exact
brute force over the LIVE corpus, and mutation latency is reported next
to query throughput.  Compaction auto-triggers on the tombstone
threshold (DESIGN.md §7).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brute_force_knn, recall_at_k, vecstore
from repro.core.distributed import distributed_search
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.pools import Pool
from repro.core.search import medoid, search
from repro.data import synthetic
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--index", required=True)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ef", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "ref", "xla"],
                    help="kernel backend for the search "
                         "(default: current REPRO_KERNEL_BACKEND/auto)")
    ap.add_argument("--visited", default="dense",
                    choices=["dense", "hashed"],
                    help="visited-set representation")
    ap.add_argument("--visited-cap", type=int, default=None,
                    help="hashed-table slots per query "
                         "(default: core.search.default_visited_cap(ef))")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard query batches over this many devices "
                         "(0 = single-device search)")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="traversal-tier vector storage (DESIGN.md §8); "
                         "int8 rescores the final candidates against the "
                         "fp32 tier unless --no-rescore")
    ap.add_argument("--no-rescore", action="store_true",
                    help="skip the fp32 rescoring pass (quantized "
                         "precisions only; shows the raw traversal-space "
                         "recall)")
    ap.add_argument("--mutable", action="store_true",
                    help="serve through a DynamicIndex with per-batch "
                         "insert/delete churn (see module docstring)")
    ap.add_argument("--churn", type=int, default=None,
                    help="vectors inserted AND deleted per batch "
                         "(only with --mutable; default 64)")
    ap.add_argument("--refine-rounds", type=int, default=None,
                    help="localized propagation rounds per insert batch "
                         "(only with --mutable; default 2)")
    args = ap.parse_args()

    if args.visited_cap is not None and args.visited != "hashed":
        ap.error("--visited-cap only applies with --visited hashed "
                 "(dense mode would silently ignore it)")
    if args.shards > len(jax.devices()):
        ap.error(f"--shards {args.shards} exceeds the {len(jax.devices())} "
                 "available device(s); on a CPU box force host devices with "
                 f"XLA_FLAGS=--xla_force_host_platform_device_count={args.shards}")
    if args.shards > 0 and args.mutable:
        ap.error("--mutable currently serves single-device (the mutation "
                 "path is not query-sharded); drop --shards")
    if not args.mutable and (args.churn is not None
                             or args.refine_rounds is not None):
        ap.error("--churn/--refine-rounds only apply with --mutable")
    if args.no_rescore and args.precision == "fp32":
        ap.error("--no-rescore only applies with --precision bf16/int8 "
                 "(fp32 traversal is already exact)")

    if args.backend is not None:
        ops.set_backend(args.backend)

    blob = np.load(args.index)
    x = jnp.asarray(blob["x"])
    ids = jnp.asarray(blob["ids"])

    if args.mutable:
        serve_mutable(args, x, jnp.asarray(blob["dists"]), ids)
        return

    # the precision ladder (DESIGN.md §8): traversal reads the compact
    # tier; the fp32 array stays around only as the rescoring tier
    store = vecstore.encode(x, args.precision)
    xt = x if args.precision == "fp32" else store
    rescore = x if (args.precision != "fp32" and not args.no_rescore) else None
    bpv = store.bytes_per_vector()
    entry = medoid(xt)

    mesh = None
    if args.shards > 0:
        mesh = jax.make_mesh((args.shards,), ("data",),
                             devices=jax.devices()[:args.shards])
        # replicate the index across the mesh ONCE; the per-batch
        # device_put inside distributed_search then no-ops on x/ids
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        xt = jax.tree.map(lambda a: jax.device_put(a, rep), xt)
        ids = jax.device_put(ids, rep)
        entry = jax.device_put(entry, rep)
        if rescore is not None:
            rescore = jax.device_put(rescore, rep)

    def run_batch(q):
        kw = dict(k=args.k, ef=args.ef, entry=entry, visited=args.visited,
                  visited_cap=args.visited_cap, rescore=rescore)
        if mesh is None:
            return search(xt, ids, q, **kw)
        return distributed_search(mesh, ("data",), xt, ids, q, **kw)

    lat, recs = [], []
    for b in range(args.batches + 1):
        q = synthetic.queries_from(jax.random.PRNGKey(100 + b), x,
                                   args.batch_size)
        t0 = time.perf_counter()
        res = run_batch(q)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        if b == 0:
            continue  # compile batch
        lat.append(dt)
        gt = brute_force_knn(x, q, args.k)
        recs.append(recall_at_k(res.ids, gt))

    qps = args.batch_size / (sum(lat) / len(lat))
    print(f"qps={qps:.0f}  p50={sorted(lat)[len(lat)//2]*1e3:.1f}ms  "
          f"recall@{args.k}={sum(recs)/len(recs):.3f}  "
          f"backend={ops.effective_backend()}  visited={args.visited}  "
          f"precision={args.precision}  bpv={bpv:.0f}  "
          f"rescore={int(rescore is not None)}  "
          f"shards={max(args.shards, 1)}")


def serve_mutable(args, x, dists, ids):
    """--mutable: per-batch insert/delete churn through a DynamicIndex.

    Only batch 0 is excluded as the compile batch: a mid-run capacity
    doubling or auto-compaction changes buffer shapes and retraces the
    jits, and those seconds land in the reported latencies — faithful for
    an ops view of steady-state serving (stalls included), but use
    benchmarks/fig10_churn.py (which warms an exact replay) for clean
    mutation-throughput numbers.
    """
    rounds = args.refine_rounds if args.refine_rounds is not None else 2
    idx = DynamicIndex(x, Pool(ids, dists),
                       DynamicConfig(refine_rounds=rounds,
                                     precision=args.precision))
    churn = args.churn if args.churn is not None else 64
    mut_lat, lat, recs = [], [], []
    for b in range(args.batches + 1):
        kb = jax.random.PRNGKey(100 + b)
        t0 = time.perf_counter()
        if churn > 0:
            idx.insert(synthetic.queries_from(kb, x, churn, noise=0.1))
            live = idx.labels[:idx.size][np.asarray(idx.valid[:idx.size])]
            idx.delete(live[:churn])  # oldest live: a sliding-window corpus
        t_mut = time.perf_counter() - t0

        q = synthetic.queries_from(jax.random.fold_in(kb, 1), x,
                                   args.batch_size)
        t0 = time.perf_counter()
        res = idx.search(q, k=args.k, ef=args.ef, visited=args.visited,
                         visited_cap=args.visited_cap,
                         rescore=False if args.no_rescore else None)
        res.dists.block_until_ready()
        dt = time.perf_counter() - t0
        if b == 0:
            continue  # compile batch
        mut_lat.append(t_mut)
        lat.append(dt)
        recs.append(recall_at_k(res.ids, idx.exact_knn(q, args.k)))

    qps = args.batch_size / (sum(lat) / len(lat))
    mut_per_s = 2 * churn / (sum(mut_lat) / len(mut_lat)) if churn else 0.0
    print(f"qps={qps:.0f}  p50={sorted(lat)[len(lat)//2]*1e3:.1f}ms  "
          f"recall@{args.k}={sum(recs)/len(recs):.3f}  "
          f"mutations/s={mut_per_s:.0f}  churn={churn}  "
          f"live={idx.n_live}  tomb={idx.tombstone_fraction:.2f}  "
          f"rounds={idx.rounds_run}  "
          f"backend={ops.effective_backend()}  visited={args.visited}  "
          f"precision={args.precision}  mutable=1")


if __name__ == "__main__":
    main()
