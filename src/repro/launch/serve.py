"""Serve batched ANN queries against a saved GRNND index.

    PYTHONPATH=src python -m repro.launch.serve --index /tmp/sift.idx.npz \
        [--batches 8] [--ef 48]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brute_force_knn, recall_at_k
from repro.core.search import search
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", required=True)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ef", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    blob = np.load(args.index)
    x = jnp.asarray(blob["x"])
    ids = jnp.asarray(blob["ids"])

    lat, recs = [], []
    for b in range(args.batches + 1):
        q = synthetic.queries_from(jax.random.PRNGKey(100 + b), x,
                                   args.batch_size)
        t0 = time.perf_counter()
        res = search(x, ids, q, k=args.k, ef=args.ef)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        if b == 0:
            continue  # compile batch
        lat.append(dt)
        gt = brute_force_knn(x, q, args.k)
        recs.append(recall_at_k(res.ids, gt))

    qps = args.batch_size / (sum(lat) / len(lat))
    print(f"qps={qps:.0f}  p50={sorted(lat)[len(lat)//2]*1e3:.1f}ms  "
          f"recall@{args.k}={sum(recs)/len(recs):.3f}")


if __name__ == "__main__":
    main()
