"""Abstract input specs + step functions for the multi-pod dry-run.

Everything here is ShapeDtypeStruct-based: no parameter or activation is
ever allocated.  Each (arch x shape) cell provides:

  * abstract arguments with NamedShardings attached (weak-type-correct), and
  * the step function to lower: train_step / prefill_step / decode_step.

The GRNND build itself is dry-run as the pseudo-arch "grnnd-ann" (the
paper's technique on the production mesh).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PSpec

from repro.configs import get_arch
from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.distributed import hints as H
from repro.distributed import sharding as SH
from repro.models import transformer as T
from repro.train import optimizer as O
from repro.train import train_step as TS

PARAM_DTYPE = jnp.float32
ACT_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


def _with_hints(fn: Callable, mesh: Mesh, fsdp: bool = False) -> Callable:
    """Trace `fn` under mesh hints: model blocks emit their explicitly-
    sharded variants (EP MoE via shard_map, per-scan-iteration FSDP
    gathers, etc.)."""
    def wrapped(*args):
        with H.use_hints(mesh, fsdp=fsdp):
            return fn(*args)
    return wrapped


def parallelism_policy(cfg: ArchConfig, shape: ShapeConfig,
                       mesh: Mesh) -> str:
    """"tp" (shard params over model) or "dp_only" (replicate params, use
    the model axis as extra data parallelism).

    TP on a model whose layers are ~100 MB total cannot amortize the
    per-layer activation collectives: a 130M model on TP=16 spends 60x
    more time in all-gather/all-reduce than in compute (measured — see
    EXPERIMENTS.md §Perf iteration m1).  Rule: replicate when the whole
    model fits a single chip's HBM with room for optimizer state (<1B
    params) AND the global batch can use the freed axis.
    """
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    if cfg.param_count() < 1e9 and shape.global_batch % n_chips == 0:
        return "dp_only"
    # fp32 params + Adam = 12 bytes/param resident; TP-only residency is
    # param_count*12/|model|.  Above ~12 GiB/chip: first try ZeRO-1
    # (optimizer state sharded over data — no per-layer weight gathers);
    # if the fp32 params ALONE exceed the budget, full FSDP (§Perf A5).
    model_par = mesh.shape.get("model", 1)
    p = cfg.param_count()
    if p * 12 / model_par > 12e9:
        if p * 4 / model_par > 12e9:
            return "fsdp"
        return "zero1"
    return "tp"


def abstract_params(cfg: ArchConfig, mesh: Mesh, tp: bool = True,
                    fsdp: bool = False):
    shapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg, dtype=PARAM_DTYPE))
    return SH.with_shardings(
        shapes, SH.param_shardings(mesh, shapes, tp=tp, fsdp=fsdp))


def abstract_opt_state(cfg: ArchConfig, mesh: Mesh, params_abs,
                       tp: bool = True, fsdp: bool = False):
    shapes = jax.eval_shape(O.init, params_abs)
    return SH.with_shardings(
        shapes, SH.opt_state_shardings(mesh, shapes, tp=tp, fsdp=fsdp))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                batch_axes=None) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.modality == "audio_tokens":
        shapes = {"tokens": jax.ShapeDtypeStruct((b, s, cfg.n_codebooks),
                                                 jnp.int32)}
    elif cfg.modality == "vision_text":
        shapes = {
            "tokens": jax.ShapeDtypeStruct((b, s - cfg.vision_tokens),
                                           jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.vision_dim), ACT_DTYPE),
        }
    else:
        shapes = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return SH.with_shardings(
        shapes, SH.batch_shardings(mesh, shapes, batch_axes=batch_axes))


def cache_specs(cfg: ArchConfig, batch: int, s_max: int, mesh: Mesh):
    shapes = jax.eval_shape(
        lambda: T.make_cache(cfg, batch, s_max, dtype=CACHE_DTYPE))
    return SH.with_shardings(shapes, SH.cache_shardings(mesh, shapes))


def token_specs(cfg: ArchConfig, b: int, mesh: Mesh):
    daxes = SH.data_axes(mesh)
    tok_shape = (b, cfg.n_codebooks) if cfg.modality == "audio_tokens" \
        else (b,)
    spec = PSpec(daxes) if b % SH._axsize(mesh, daxes) == 0 and b > 1 \
        else PSpec()
    tok = jax.ShapeDtypeStruct(tok_shape, jnp.int32,
                               sharding=NamedSharding(mesh, spec))
    pos = jax.ShapeDtypeStruct((b,), jnp.int32,
                               sharding=NamedSharding(mesh, tok.sharding.spec
                                                      if b > 1 else PSpec()))
    return tok, pos


# ---------------------------------------------------------------------------
# step functions per shape kind
# ---------------------------------------------------------------------------

def make_cell(arch_name: str, shape_name: str, mesh: Mesh,
              ce_chunk: int = 512, cost_probe: int = 0,
              cfg_override: ArchConfig | None = None,
              remat_policy: str = "full",
              ) -> tuple[Callable, tuple]:
    """Returns (fn, abstract_args) for one dry-run cell.

    cost_probe=k > 0 truncates the arch to k pattern units and fully
    unrolls the layer scans (and widens the CE chunk to one piece): XLA's
    cost_analysis counts a while-loop body once, so true costs come from
    the k=1/k=2 probes extrapolated linearly over the real unit count.
    """
    if arch_name == "grnnd-ann":
        return _grnnd_cell(shape_name, mesh)

    from repro.configs.base import truncate_units
    cfg = cfg_override if cfg_override is not None else get_arch(arch_name)
    unroll = False
    if cost_probe:
        cfg = truncate_units(cfg, cost_probe)
        unroll = True
        ce_chunk = 1 << 30
    shape = SHAPES[shape_name]
    params_abs = abstract_params(cfg, mesh)

    if shape.kind == "train":
        policy = parallelism_policy(cfg, shape, mesh)
        if policy == "dp_only":
            all_axes = tuple(a for a in ("pod", "data", "model")
                             if a in mesh.shape)
            params_abs = abstract_params(cfg, mesh, tp=False)
            opt_abs = abstract_opt_state(cfg, mesh, params_abs, tp=False)
            batch_abs = batch_specs(cfg, shape, mesh, batch_axes=all_axes)
        elif policy == "fsdp":
            params_abs = abstract_params(cfg, mesh, fsdp=True)
            opt_abs = abstract_opt_state(cfg, mesh, params_abs, fsdp=True)
            batch_abs = batch_specs(cfg, shape, mesh)
        elif policy == "zero1":
            # params stay TP-resident; only Adam mu/nu shard over data
            opt_abs = abstract_opt_state(cfg, mesh, params_abs, fsdp=True)
            batch_abs = batch_specs(cfg, shape, mesh)
        else:
            opt_abs = abstract_opt_state(cfg, mesh, params_abs)
            batch_abs = batch_specs(cfg, shape, mesh)
        state_abs = TS.TrainState(params_abs, opt_abs)
        opt_cfg = O.AdamWConfig()
        step = TS.make_train_step(cfg, opt_cfg, act_dtype=ACT_DTYPE,
                                  ce_chunk=ce_chunk, scan_unroll=unroll,
                                  remat_policy=remat_policy)
        return _with_hints(step, mesh, fsdp=(policy == "fsdp")), \
            (state_abs, batch_abs)

    if shape.kind == "prefill":
        batch_abs = batch_specs(cfg, shape, mesh)

        def prefill_step(params, batch):
            logits, caches, _ = T.prefill(params, cfg, batch,
                                          act_dtype=ACT_DTYPE,
                                          scan_unroll=unroll)
            return logits, caches

        return _with_hints(prefill_step, mesh), (params_abs, batch_abs)

    # decode: one new token against a seq_len cache
    b, s = shape.global_batch, shape.seq_len
    caches_abs = cache_specs(cfg, b, s, mesh)
    tok_abs, pos_abs = token_specs(cfg, b, mesh)

    def decode(params, caches, tokens, pos):
        return T.decode_step(params, cfg, caches, tokens, pos,
                             act_dtype=ACT_DTYPE, scan_unroll=unroll)

    return _with_hints(decode, mesh), (params_abs, caches_abs, tok_abs,
                                       pos_abs)


# ---------------------------------------------------------------------------
# the paper's own technique on the production mesh
# ---------------------------------------------------------------------------

GRNND_SHAPES = {
    "build_1m_d128": dict(n=1_048_576, d=128),
    "build_1m_d960": dict(n=1_048_576, d=960),
}


def _grnnd_cell(shape_name: str, mesh: Mesh):
    from repro.core import distributed as D
    from repro.core.grnnd import GRNNDConfig

    spec = GRNND_SHAPES[shape_name]
    n, d = spec["n"], spec["d"]
    # perf iteration g2: vertices shard over EVERY mesh axis — GRNND has no
    # tensor-parallel dimension, so an idle "model" axis silently
    # replicates all per-vertex work 16x (measured in §Perf).
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    cfg = GRNNDConfig(s=24, r=48, t1=4, t2=6, pairs_per_vertex=48,
                      chunk_size=None)

    build_round = D.make_sharded_builder(mesh, axes, cfg, comm="a2a")

    vshard = NamedSharding(mesh, PSpec(axes))
    rshard = NamedSharding(mesh, PSpec())
    x_abs = jax.ShapeDtypeStruct((n, d), jnp.float32, sharding=rshard)
    ids_abs = jax.ShapeDtypeStruct((n, cfg.r), jnp.int32, sharding=vshard)
    dists_abs = jax.ShapeDtypeStruct((n, cfg.r), jnp.float32, sharding=vshard)
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rshard)

    def round_fn(x, ids, dists, key):
        pool = D.P.Pool(ids, dists)
        out = build_round(x, pool, key)
        return out.ids, out.dists

    return round_fn, (x_abs, ids_abs, dists_abs, key_abs)


def cell_is_applicable(arch_name: str, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per DESIGN.md §5."""
    if arch_name == "grnnd-ann":
        return shape_name in GRNND_SHAPES, "grnnd shapes only"
    cfg = get_arch(arch_name)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention stack: no sub-quadratic "
                       "structure for 524k decode (DESIGN.md §5)")
    return True, ""
