"""Training driver: --arch <id> [--steps N] with checkpoint/restart.

CPU-scale by default (reduced config); pass --full for the real config (on
TPU hardware).  Wires together: config -> model init -> sharded train step
-> deterministic data pipeline -> checkpoint manager -> metrics log.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as CKPT
from repro.configs import get_arch, reduced
from repro.data import pipeline as PIPE
from repro.models import transformer as T
from repro.train import optimizer as O
from repro.train import train_step as TS


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 128,
          full: bool = False, ckpt_dir: str | None = None,
          save_every: int = 50, lr: float = 3e-4,
          log_every: int = 10, resume: bool = True,
          act_dtype=jnp.float32, stop_at: int | None = None):
    cfg = get_arch(arch)
    if not full:
        cfg = reduced(cfg)

    opt_cfg = O.AdamWConfig(lr=lr, total_steps=steps, warmup_steps=steps // 10)
    step_fn = jax.jit(TS.make_train_step(cfg, opt_cfg, act_dtype=act_dtype))

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    state = TS.TrainState(params, O.init(params))

    start = 0
    if ckpt_dir and resume and (last := CKPT.latest_step(ckpt_dir)) is not None:
        state = CKPT.restore(ckpt_dir, last, state)
        start = last
        print(f"resumed from step {last}")

    history = []
    t0 = time.time()
    # stop_at simulates preemption: schedule stays tied to `steps`
    end = min(steps, stop_at) if stop_at is not None else steps
    for step in range(start, end):
        batch_data = PIPE.batch_for_step(cfg, step, batch, seq)
        state, metrics = step_fn(state, batch_data)
        if (step + 1) % log_every == 0 or step == start:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step + 1
            m["wall_s"] = round(time.time() - t0, 1)
            history.append(m)
            print(f"step {step+1:5d}  loss {m['loss']:.4f}  "
                  f"ce {m['ce']:.4f}  gnorm {m['grad_norm']:.3f}", flush=True)
        if ckpt_dir and (step + 1) % save_every == 0:
            CKPT.save(ckpt_dir, step + 1, state)
            CKPT.prune_old(ckpt_dir)
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    _, history = train(args.arch, steps=args.steps, batch=args.batch,
                       seq=args.seq, full=args.full, ckpt_dir=args.ckpt_dir,
                       lr=args.lr)
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(history, indent=2))


if __name__ == "__main__":
    main()
