"""Attention: GQA with RoPE, qk-norm, soft-capping, global/local (sliding
window) variants, blockwise (flash-style) computation for long sequences,
and single-token decode against a KV cache.

Layout conventions:
  activations x        (B, S, D)
  q                    (B, S, H, Dh)
  k, v                 (B, S, K, Dh)        K = n_kv_heads, G = H // K
  KV cache             (B, S_max, K, Dh)

Blockwise attention scans q-chunks (outer) and kv-chunks (inner) with an
online-softmax carry — the memory-bounded formulation that long-context
prefill requires (a 32k x 32k score matrix must never materialize), and the
natural TPU structure (each chunk pair is an MXU-shaped matmul).
Local layers slice a fixed-size KV window per q-chunk instead of scanning
all of KV: O(S·window) compute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.configs.base import ArchConfig

NEG_INF = -2.0 ** 30  # large-negative instead of -inf: keeps softmax NaN-free


def init_attn_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h, k_, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = L.split_keys(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (d, h, dh), dtype=dtype),
        "wk": L.dense_init(ks[1], (d, k_, dh), dtype=dtype),
        "wv": L.dense_init(ks[2], (d, k_, dh), dtype=dtype),
        "wo": L.dense_init(ks[3], (h, dh, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dtype)
        p["k_norm"] = jnp.zeros((dh,), dtype)
    return p


def qkv(params, cfg: ArchConfig, x, positions):
    """Project + RoPE. x (B,S,D), positions (B,S) -> q,k,v."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, params["k_norm"], cfg.norm_eps)
    sin, cos = L.rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, sin, cos)
    k = L.apply_rope(k, sin, cos)
    return q, k, v


def _scores(q, k, cfg: ArchConfig):
    """q (B,Sq,H,Dh), k (B,Sk,K,Dh) -> (B,K,G,Sq,Sk) softcapped/scaled."""
    b, sq, h, dh = q.shape
    kk = k.shape[2]
    g = h // kk
    qg = q.reshape(b, sq, kk, g, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k) * (dh ** -0.5)
    return L.softcap(s.astype(jnp.float32), cfg.attn_softcap)


def _combine(scores, v):
    """scores (B,K,G,Sq,Sk) fp32, v (B,Sk,K,Dh) -> (B,Sq,H,Dh)."""
    b, kk, g, sq, sk = scores.shape
    out = jnp.einsum("bkgst,btkd->bskgd", scores.astype(v.dtype), v)
    return out.reshape(b, sq, kk * g, v.shape[-1])


def full_attention(q, k, v, cfg: ArchConfig, q_pos, k_pos, window: int = 0):
    """Materialized-score attention (small S / decode / smoke tests)."""
    s = _scores(q, k, cfg)                                    # (B,K,G,Sq,Sk)
    mask = q_pos[:, None] >= k_pos[None, :]                   # causal
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return _combine(w, v)


def blockwise_attention(
    q, k, v, cfg: ArchConfig, *,
    window: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
):
    """Online-softmax attention over chunk pairs; causal; optional window.

    Global layers: inner scan over all KV chunks (skippable chunks are still
    computed but fully masked — XLA's CSE keeps this simple; the perf pass
    can early-exit).  Local layers: a single fixed-size KV slice per q-chunk.
    """
    b, s, h, dh = q.shape
    q_chunk = min(q_chunk, s)
    while s % q_chunk:
        q_chunk //= 2
    nq = s // q_chunk

    def one_q_chunk(carry, qi):
        p0 = qi * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q, p0, q_chunk, axis=1)
        q_pos = p0 + jnp.arange(q_chunk)

        if window:
            w = window
            lsize = min(w + q_chunk, s)
            start = jnp.clip(p0 + q_chunk - lsize, 0, s - lsize)
            kc = jax.lax.dynamic_slice_in_dim(k, start, lsize, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, lsize, axis=1)
            k_pos = start + jnp.arange(lsize)
            sc = _scores(qc, kc, cfg)
            mask = (q_pos[:, None] >= k_pos[None, :]) & \
                   (q_pos[:, None] - k_pos[None, :] < w)
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            out = _combine(jax.nn.softmax(sc, axis=-1), vc)
            return carry, out

        kv_c = min(kv_chunk, s)
        while s % kv_c:
            kv_c //= 2
        nkv = s // kv_c
        kk = k.shape[2]
        g = h // kk

        def one_kv_chunk(inner, ki):
            m, l, acc = inner
            t0 = ki * kv_c
            kc = jax.lax.dynamic_slice_in_dim(k, t0, kv_c, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, t0, kv_c, axis=1)
            k_pos = t0 + jnp.arange(kv_c)
            sc = _scores(qc, kc, cfg)                       # (B,K,G,qc,kv_c)
            mask = q_pos[:, None] >= k_pos[None, :]
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(vc.dtype), vc)
            acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kk, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kk, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kk, g, q_chunk, dh), v.dtype)
        (m, l, acc), _ = jax.lax.scan(
            one_kv_chunk, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        out = jnp.moveaxis(out, 3, 1).reshape(b, q_chunk, h, dh)
        return carry, out

    _, outs = jax.lax.scan(one_q_chunk, (), jnp.arange(nq))
    # outs: (nq, B, q_chunk, H, Dh) -> (B, S, H, Dh)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dh)


def decode_attention(q1, cache_k, cache_v, cfg: ArchConfig, pos, window: int = 0):
    """One-token attention: q1 (B,1,H,Dh) against cache (B,Smax,K,Dh).

    `pos` (B,) is the index where the current token sits (cache already
    updated).  Mask admits cache slots <= pos (and within the window for
    local layers).
    """
    smax = cache_k.shape[1]
    sc = _scores(q1, cache_k, cfg)                       # (B,K,G,1,Smax)
    k_pos = jnp.arange(smax)
    mask = k_pos[None, :] <= pos[:, None]                # (B, Smax)
    if window:
        mask &= (pos[:, None] - k_pos[None, :]) < window
    sc = jnp.where(mask[:, None, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    return _combine(w, cache_v)


def attention_block(params, cfg: ArchConfig, x, positions, *,
                    kind: str, blockwise_threshold: int = 8192):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = qkv(params, cfg, x, positions)
    window = cfg.window if kind == "local" else 0
    s = x.shape[1]
    if s > blockwise_threshold or (window and s > 2 * window):
        out = blockwise_attention(q, k, v, cfg, window=window)
    else:
        qp = positions[0]
        out = full_attention(q, k, v, cfg, qp, qp, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, (k, v)


def attention_decode_block(params, cfg: ArchConfig, x1, cache, pos, *, kind: str):
    """Single-token decode. x1 (B,1,D); cache dict with k/v (B,Smax,K,Dh).

    Returns (out (B,1,D), updated cache).
    """
    b = x1.shape[0]
    q, k_new, v_new = qkv(params, cfg, x1, pos[:, None])
    ck = jax.vmap(
        lambda c, upd, p: jax.lax.dynamic_update_slice_in_dim(c, upd, p, 0)
    )(cache["k"], k_new, pos)
    cv = jax.vmap(
        lambda c, upd, p: jax.lax.dynamic_update_slice_in_dim(c, upd, p, 0)
    )(cache["v"], v_new, pos)
    window = cfg.window if kind == "local" else 0
    out = decode_attention(q, ck, cv, cfg, pos, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x1.dtype))
    return out, {"k": ck, "v": cv}
