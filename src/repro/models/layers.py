"""Shared model primitives: RMSNorm, RoPE, gated MLP, soft-capping, inits."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Initializer = jax.nn.initializers.Initializer


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping; identity when cap == 0."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple:
    """positions (...,) -> (sin, cos) of shape (..., head_dim // 2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.sin(angle), jnp.cos(angle)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, Dh); sin/cos (..., S, Dh/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]  # add head axis
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def gated_mlp(x: jnp.ndarray, wi_gate, wi_up, wo, act=jax.nn.silu) -> jnp.ndarray:
    """SwiGLU-style gated MLP: (x @ Wg).act * (x @ Wu) @ Wo."""
    g = act(jnp.einsum("...d,df->...f", x, wi_gate.astype(x.dtype)))
    u = jnp.einsum("...d,df->...f", x, wi_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", g * u, wo.astype(x.dtype))


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
