"""Mixture-of-Experts: fine-grained experts, shared experts, top-k routing.

Dispatch uses the permute/capacity formulation (the same sort + segment-rank
dataflow as the GRNND request router in core/pools.py — one framework, one
idiom): token->expert assignments are sorted by expert, capacity-capped,
scattered into an (E*C, D) buffer, batched through the expert FFNs with one
(E, C, D) x (E, D, F) einsum pair, and combined back with routing weights.
Tokens over capacity are dropped (standard capacity-factor semantics).

Under pjit the expert axis shards over "model" (EP); the scatter/gather
between token-space (data-sharded) and expert-space (model-sharded) lowers
to all-to-all-style collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def init_moe_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d, e, de = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = L.split_keys(key, 7)
    p = {
        "router": L.dense_init(ks[0], (d, e), dtype=jnp.float32),  # fp32 router
        "wi_gate": L.dense_init(ks[1], (e, d, de), in_axis=1, dtype=dtype),
        "wi_up": L.dense_init(ks[2], (e, d, de), in_axis=1, dtype=dtype),
        "wo": L.dense_init(ks[3], (e, de, d), in_axis=1, dtype=dtype),
    }
    if cfg.n_shared_experts:
        f = cfg.n_shared_experts * de
        p["shared"] = {
            "wi_gate": L.dense_init(ks[4], (d, f), dtype=dtype),
            "wi_up": L.dense_init(ks[5], (d, f), dtype=dtype),
            "wo": L.dense_init(ks[6], (f, d), dtype=dtype),
        }
    return p


def _capacity(cfg: ArchConfig, t: int) -> int:
    c = int(cfg.moe_capacity_factor * t * cfg.top_k / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to a lane-friendly multiple


def _permute_ffn(params, cfg: ArchConfig, xt, probs, w, idx, *,
                 e_local: int, e_offset, wi_gate, wi_up, wo):
    """Dispatch/compute/combine for `e_local` experts starting at e_offset.

    xt (T, D); w/idx (T, k) routing weights and expert ids (global ids).
    Returns the weighted sum of local-expert outputs per token (T, D) —
    the caller psums over the expert-parallel axis if e_local < E.
    """
    t, d = xt.shape
    k = cfg.top_k

    flat_e = idx.reshape(t * k) - e_offset
    in_range = (flat_e >= 0) & (flat_e < e_local)
    flat_e = jnp.where(in_range, flat_e, e_local)          # OOB bucket
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    es = flat_e[order]
    toks = tok[order]
    pos_in = jnp.arange(t * k, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.array([True]), es[1:] != es[:-1]])
    seg0 = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, pos_in, 0))
    rank = pos_in - seg0

    c = _capacity(cfg, t)
    kept = (rank < c) & (es < e_local)
    slot = jnp.where(kept, es * c + rank, e_local * c)

    # Invert the permutation with SMALL integer scatters only: big-tensor
    # scatters lower to full-width index broadcasts (8 GiB of u32 per op at
    # this scale); with the inverse map both dispatch and combine become
    # gathers, which partition and fuse cleanly.
    row_of_slot = jnp.zeros((e_local * c,), jnp.int32) \
        .at[slot].set(toks, mode="drop")                      # (E_loc*C,)
    slot_valid = jnp.zeros((e_local * c,), jnp.bool_) \
        .at[slot].set(kept, mode="drop")
    slot_by_assign = jnp.full((t * k,), e_local * c, jnp.int32) \
        .at[order].set(jnp.where(kept, slot, e_local * c))    # (T*k,)

    buf = xt[row_of_slot] * slot_valid[:, None].astype(xt.dtype)

    h = buf.reshape(e_local, c, d)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wi_gate.astype(xt.dtype)))
    u = jnp.einsum("ecd,edf->ecf", h, wi_up.astype(xt.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", g * u,
                       wo.astype(xt.dtype)).reshape(e_local * c, d)

    # combine: gather each assignment's expert output, weight, sum over k
    sl = slot_by_assign.reshape(t, k)
    ok = sl < e_local * c
    picked = out_e[jnp.where(ok, sl, 0)]                      # (T, k, D)
    wk = jnp.where(ok, w, 0.0).astype(xt.dtype)
    y = jnp.einsum("tkd,tk->td", picked, wk)
    drop_frac = 1.0 - jnp.sum(kept.astype(jnp.float32)) / \
        jnp.maximum(jnp.sum(in_range.astype(jnp.float32)), 1.0)
    return y, drop_frac


def _moe_block_ep(params, cfg: ArchConfig, x: jnp.ndarray, hints):
    """Expert-parallel MoE via shard_map: tokens sharded over the data
    axes, experts over the model axis.  Dispatch is a LOCAL select (tokens
    are replicated across the model axis), combine is ONE psum of the
    (T_local, D) partial output — the cheapest EP dataflow for capacity-
    based routing, and the same owner-routing idiom as the GRNND
    distributed build (DESIGN.md §4.3).
    """
    from jax.sharding import PartitionSpec as PSpec

    from repro.compat import shard_map

    b, s, d = x.shape
    e = cfg.n_experts
    m_ax = hints.model_axis
    n_ep = hints.mesh.shape[m_ax]
    assert e % n_ep == 0
    e_loc = e // n_ep

    tspec = PSpec(hints.data_axes, None)       # tokens over data axes
    espec = PSpec(m_ax)                        # experts over model

    def body(xt, router, wi_gate, wi_up, wo):
        ridx = jax.lax.axis_index(m_ax)
        e0 = ridx * e_loc
        # router matmul in activation dtype: an fp32 (T, D) input would
        # materialize an 8 GiB fp32 tensor + its VJP per layer; fp32
        # precision is only needed on the tiny (T, E) logits.
        logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        y_part, drop = _permute_ffn(
            params, cfg, xt, probs, w, idx, e_local=e_loc, e_offset=e0,
            wi_gate=wi_gate, wi_up=wi_up, wo=wo)
        y = jax.lax.psum(y_part, m_ax)
        # load-balance stats via bincount scatter (no (T, k, E) one-hot)
        counts = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
        me = counts / jnp.maximum(jnp.sum(counts), 1.0)
        pe = jnp.mean(probs, axis=0)
        lb = e * jnp.sum(me * pe)
        return y, lb, jax.lax.pmean(drop, m_ax)

    xt = x.reshape(b * s, d)
    y, lb, drop = shard_map(
        body, mesh=hints.mesh,
        in_specs=(tspec, PSpec(), espec, espec, espec),
        out_specs=(tspec, PSpec(), PSpec()),
        check_vma=False,
    )(xt, params["router"], params["wi_gate"], params["wi_up"],
      params["wo"])

    if cfg.n_shared_experts:
        sp = params["shared"]
        y = y + L.gated_mlp(xt, sp["wi_gate"], sp["wi_up"], sp["wo"])
    aux = {"moe_lb_loss": lb, "moe_drop_frac": drop}
    return y.reshape(b, s, d), aux


def moe_block(params, cfg: ArchConfig, x: jnp.ndarray):
    """x (B, S, D) -> (out (B, S, D), aux metrics dict)."""
    from repro.distributed import hints as H
    hints = H.get_hints()
    if hints is not None and hints.model_axis is not None \
            and cfg.n_experts % hints.mesh.shape[hints.model_axis] == 0:
        return _moe_block_ep(params, cfg, x, hints)

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ params["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                            # (T, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    # ---- permute: sort assignments by expert, rank within segment ----
    flat_e = idx.reshape(t * k)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    es = flat_e[order]
    toks = tok[order]
    pos_in = jnp.arange(t * k, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.array([True]), es[1:] != es[:-1]])
    seg0 = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, pos_in, 0))
    rank = pos_in - seg0

    c = _capacity(cfg, t)
    kept = rank < c
    slot = jnp.where(kept, es * c + rank, e * c)                # OOB = drop

    buf = jnp.zeros((e * c, d), x.dtype)
    buf = buf.at[slot].set(xt[toks], mode="drop")

    # ---- expert FFNs (SwiGLU), batched einsum over the expert axis ----
    h = buf.reshape(e, c, d)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h,
                               params["wi_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", h, params["wi_up"].astype(x.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", g * u,
                       params["wo"].astype(x.dtype)).reshape(e * c, d)

    # ---- unpermute: gather each kept assignment's output, weight, sum ----
    safe_slot = jnp.where(kept, slot, 0)
    y_sorted = jnp.where(kept[:, None], out_e[safe_slot], 0.0)  # (T*k, D)
    w_sorted = w.reshape(t * k)[order]
    contrib = y_sorted * w_sorted[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[toks].add(contrib)

    # ---- shared experts (dense path over all tokens) ----
    if cfg.n_shared_experts:
        sp = params["shared"]
        y = y + L.gated_mlp(xt, sp["wi_gate"], sp["wi_up"], sp["wo"])

    # ---- aux: load-balance loss (Switch-style) + drop fraction ----
    me = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1))
    pe = jnp.mean(probs, axis=0)
    aux = {
        "moe_lb_loss": e * jnp.sum(me * pe),
        "moe_drop_frac": 1.0 - jnp.mean(kept.astype(jnp.float32)),
    }
    return y.reshape(b, s, d), aux
