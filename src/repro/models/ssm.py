"""Mamba2 (SSD — state-space duality) block: chunked train/prefill scan,
O(1)-state decode, and a naive recurrence oracle for tests.

Recurrence (per batch, per head; state h in R^{hd x st}):
    h_t = a_t * h_{t-1} + (dt_t * x_t) b_t^T          a_t = exp(dt_t * A)
    y_t = h_t c_t + D * x_t

The chunked (SSD) formulation splits S into chunks of Q: within a chunk the
output is an attention-like masked matmul against the decay matrix; across
chunks a scan carries the (nh, hd, st) state.  This is the TPU-native
structure: both the intra-chunk part and the state updates are MXU matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def init_ssm_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner
    st = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_dim = di + 2 * st
    d_in_proj = 2 * di + 2 * st + nh
    ks = L.split_keys(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], (d, d_in_proj), dtype=dtype),
        "conv_w": L.dense_init(ks[1], (cfg.ssm_conv, conv_dim), dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": L.dense_init(ks[3], (di, d), dtype=dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * st]
    dt = zxbcdt[..., di + di + 2 * st:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv over (B, S, C) with taps (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + bias[None, None, :])


def _ssd_chunked(xh, a, b, c, h0, chunk: int):
    """Chunked SSD scan.

    xh (B,S,nh,hd) — dt-scaled inputs;  a (B,S,nh) — per-step decay in (0,1];
    b, c (B,S,st);  h0 (B,nh,hd,st) initial state.
    Returns (y (B,S,nh,hd), h_final).
    """
    bsz, s, nh, hd = xh.shape
    st = b.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nchunks = s // q

    xh_c = xh.reshape(bsz, nchunks, q, nh, hd)
    a_c = a.reshape(bsz, nchunks, q, nh)
    b_c = b.reshape(bsz, nchunks, q, st)
    c_c = c.reshape(bsz, nchunks, q, st)

    la = jnp.log(jnp.maximum(a_c, 1e-37))
    cum = jnp.cumsum(la, axis=2)                         # (B,NC,Q,nh) log prod_{t<=i}

    def step(h, inp):
        xh_i, a_i, b_i, c_i, cum_i, la_i = inp           # chunk tensors (B,Q,...)
        # intra-chunk: y[i] = sum_{j<=i} (c_i.b_j) exp(cum_i - cum_j) xh[j]
        li = cum_i[:, :, None, :] - cum_i[:, None, :, :]  # (B,Q,Q,nh) log decay i<-j
        causal = jnp.tril(jnp.ones((q, q), bool))
        # mask in LOG space: exp of masked-out (positive) entries would
        # overflow to inf and poison gradients through the where.
        li = jnp.where(causal[None, :, :, None], li, -1e30)
        dec = jnp.exp(li)
        cb = jnp.einsum("bis,bjs->bij", c_i, b_i)         # (B,Q,Q)
        # NOTE (perf iteration m2, refuted): casting this contraction to
        # bf16 was hypothesized to cut the memory term ~15%; measured
        # bytes went UP 4% (extra convert traffic) and SSD accuracy left
        # the 1e-4 envelope — reverted.  See EXPERIMENTS.md §Perf.
        y_intra = jnp.einsum("bij,bijh,bjhd->bihd",
                             cb, dec, xh_i)
        # inter-chunk: y[i] += (prod_{t<=i} a) * c_i^T h_in
        y_inter = jnp.einsum("bis,bhds,bih->bihd",
                             c_i, h, jnp.exp(cum_i))
        y = y_intra + y_inter
        # state update: h_out = (prod_chunk a) h_in + sum_j (prod_{t>j} a) xh_j b_j^T
        tot = cum_i[:, -1, :]                             # (B,nh)
        rem = tot[:, None, :] - cum_i                     # (B,Q,nh) log prod_{t>j}
        h_new = jnp.exp(tot)[:, :, None, None] * h + jnp.einsum(
            "bjh,bjhd,bjs->bhds", jnp.exp(rem), xh_i, b_i)
        return h_new, y

    xs = (
        jnp.moveaxis(xh_c, 1, 0), jnp.moveaxis(a_c, 1, 0),
        jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0),
        jnp.moveaxis(cum, 1, 0), jnp.moveaxis(la.reshape(bsz, nchunks, q, nh), 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, nh, hd)
    return y, h_final


def ssd_naive(xh, a, b, c, h0):
    """Sequential oracle for tests: same signature as _ssd_chunked."""
    def step(h, inp):
        xh_t, a_t, b_t, c_t = inp
        h = a_t[:, :, None, None] * h + jnp.einsum("bhd,bs->bhds", xh_t, b_t)
        y = jnp.einsum("bhds,bs->bhd", h, c_t)
        return h, y
    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h


def ssm_block(params, cfg: ArchConfig, x, *, h0=None, return_cache=False):
    """Full-sequence Mamba2 block. x (B,S,D) -> (B,S,D) [, cache]."""
    bsz, s, _ = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, params["conv_w"].astype(x.dtype),
                       params["conv_b"].astype(x.dtype))
    xs = xbc[..., :di].reshape(bsz, s, nh, hd).astype(jnp.float32)
    b = xbc[..., di:di + st].astype(jnp.float32)
    c = xbc[..., di + st:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(-jnp.exp(params["A_log"])[None, None, :] * dt)   # (B,S,nh)
    xh = xs * dt[..., None]

    if h0 is None:
        h0 = jnp.zeros((bsz, nh, hd, st), jnp.float32)
    y, h_final = _ssd_chunked(xh, a, b, c, h0, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xs
    y = y.reshape(bsz, s, di).astype(x.dtype)

    y = L.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    if return_cache:
        width = cfg.ssm_conv
        # pre-activation xbc tail for the decode conv window
        zxbcdt_tail = zxbcdt[:, -(width - 1):, :]
        _, xbc_raw, _ = _split_proj(cfg, zxbcdt_tail)
        return out, {"h": h_final, "conv": xbc_raw}
    return out


def ssm_decode_block(params, cfg: ArchConfig, x1, cache):
    """Single-token decode. x1 (B,1,D); cache {h (B,nh,hd,st), conv (B,W-1,C)}."""
    bsz = x1.shape[0]
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    width = cfg.ssm_conv

    zxbcdt = jnp.einsum("bsd,de->bse", x1, params["in_proj"].astype(x1.dtype))
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)                  # (B,1,·)

    conv_win = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B,W,C)
    w = params["conv_w"].astype(x1.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", conv_win, w) + params["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None, :]                    # (B,1,C)

    xs = xbc[..., :di].reshape(bsz, nh, hd).astype(jnp.float32)
    b = xbc[:, 0, di:di + st].astype(jnp.float32)
    c = xbc[:, 0, di + st:].astype(jnp.float32)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(-jnp.exp(params["A_log"])[None, :] * dt)       # (B,nh)
    xh = xs * dt[..., None]

    h = a[:, :, None, None] * cache["h"] + jnp.einsum("bhd,bs->bhds", xh, b)
    y = jnp.einsum("bhds,bs->bhd", h, c) + params["D"][None, :, None] * xs
    y = y.reshape(bsz, 1, di).astype(x1.dtype)

    y = L.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x1.dtype))
    return out, {"h": h, "conv": conv_win[:, 1:, :]}
