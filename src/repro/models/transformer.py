"""Model assembly: config-driven decoder stacks for all assigned families.

Layers are grouped into the arch's repeating pattern unit and scanned with
jax.lax.scan over stacked parameters (leading axis = number of repeats) —
this keeps HLO size O(pattern) instead of O(n_layers), which matters for
62/81/94-layer configs at 512-device compile.

Heterogeneous patterns (gemma3's 5 local + 1 global, zamba2's 5 ssm +
shared-attn) are expressed as a *segment* = (tuple of per-position layer
descriptors, n_repeats); non-divisible tails get their own 1-repeat segment.
Zamba2's shared attention block has ONE parameter set (not scanned) applied
at every `shared_attn` position — each occurrence keeps its own KV cache.

Modality frontends per the assignment: audio ([B,S,ncb] token grids, summed
codebook embeddings, per-codebook heads) and vision (precomputed patch
embeddings projected into the first `vision_tokens` positions) are stubs at
the input_specs level; everything downstream is real.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Params = Any


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

def layer_descs(cfg: ArchConfig) -> list[tuple[str, str]]:
    """Per-layer (kind, mlp_kind)."""
    out = []
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind == "ssm":
            out.append(("ssm", "none"))
        else:
            mlp = "moe" if (cfg.n_experts and i >= cfg.first_k_dense
                            and kind != "shared_attn") else "dense"
            out.append((kind, mlp))
    return out


def build_segments(cfg: ArchConfig) -> list[tuple[tuple, int]]:
    descs = layer_descs(cfg)
    segments: list[tuple[tuple, int]] = []
    i = 0
    if cfg.first_k_dense:
        segments.append((tuple(descs[:cfg.first_k_dense]), 1))
        i = cfg.first_k_dense
    body = descs[i:]
    unit = len(cfg.layer_pattern)
    if unit > len(body):
        unit = max(len(body), 1)
    n_rep = len(body) // unit
    if n_rep:
        segments.append((tuple(body[:unit]), n_rep))
    tail = body[n_rep * unit:]
    if tail:
        segments.append((tuple(tail), 1))
    return segments


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, kind: str, mlp_kind: str, dtype):
    d = cfg.d_model
    ks = L.split_keys(key, 6)
    if kind == "ssm":
        return {"ln": jnp.zeros((d,), dtype),
                "ssm": S.init_ssm_params(ks[0], cfg, dtype)}
    if kind == "shared_attn":
        return {}  # weights live in params["shared_attn"]
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "attn": A.init_attn_params(ks[0], cfg, dtype),
        "ln2": jnp.zeros((d,), dtype),
    }
    if cfg.post_norm:
        p["post_ln1"] = jnp.zeros((d,), dtype)
        p["post_ln2"] = jnp.zeros((d,), dtype)
    if mlp_kind == "moe":
        p["moe"] = M.init_moe_params(ks[1], cfg, dtype)
    else:
        p["mlp"] = {
            "wi_gate": L.dense_init(ks[2], (d, cfg.d_ff), dtype=dtype),
            "wi_up": L.dense_init(ks[3], (d, cfg.d_ff), dtype=dtype),
            "wo": L.dense_init(ks[4], (cfg.d_ff, d), dtype=dtype),
        }
    return p


def _init_shared_attn(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ks = L.split_keys(key, 5)
    return {
        "ln1": jnp.zeros((d,), dtype),
        "attn": A.init_attn_params(ks[0], cfg, dtype),
        "ln2": jnp.zeros((d,), dtype),
        "mlp": {
            "wi_gate": L.dense_init(ks[1], (d, cfg.d_ff), dtype=dtype),
            "wi_up": L.dense_init(ks[2], (d, cfg.d_ff), dtype=dtype),
            "wo": L.dense_init(ks[3], (cfg.d_ff, d), dtype=dtype),
        },
    }


def _apply_layer(p, shared_p, cfg: ArchConfig, kind: str, mlp_kind: str,
                 x, positions, aux_acc):
    """Full-sequence layer application. Returns (x, cache_entry, aux)."""
    if kind == "ssm":
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        out, cache = S.ssm_block(p["ssm"], cfg, h, return_cache=True)
        return x + out, cache, aux_acc

    lp = shared_p if kind == "shared_attn" else p
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, (k, v) = A.attention_block(
        lp["attn"], cfg, h, positions,
        kind=("global" if kind == "shared_attn" else kind))
    if cfg.post_norm:
        attn_out = L.rms_norm(attn_out, lp["post_ln1"], cfg.norm_eps)
    x = x + attn_out

    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if mlp_kind == "moe":
        mlp_out, aux = M.moe_block(lp["moe"], cfg, h)
        aux_acc = aux_acc + aux["moe_lb_loss"]
    else:
        mlp_out = L.gated_mlp(h, lp["mlp"]["wi_gate"], lp["mlp"]["wi_up"],
                              lp["mlp"]["wo"])
    if cfg.post_norm:
        mlp_out = L.rms_norm(mlp_out, lp["post_ln2"], cfg.norm_eps)
    return x + mlp_out, {"k": k, "v": v}, aux_acc


def _apply_layer_decode(p, shared_p, cfg: ArchConfig, kind: str,
                        mlp_kind: str, x1, cache, pos):
    """Single-token layer application. Returns (x1, updated cache)."""
    if kind == "ssm":
        h = L.rms_norm(x1, p["ln"], cfg.norm_eps)
        out, cache = S.ssm_decode_block(p["ssm"], cfg, h, cache)
        return x1 + out, cache

    lp = shared_p if kind == "shared_attn" else p
    h = L.rms_norm(x1, lp["ln1"], cfg.norm_eps)
    attn_out, cache = A.attention_decode_block(
        lp["attn"], cfg, h, cache, pos,
        kind=("global" if kind == "shared_attn" else kind))
    if cfg.post_norm:
        attn_out = L.rms_norm(attn_out, lp["post_ln1"], cfg.norm_eps)
    x1 = x1 + attn_out

    h = L.rms_norm(x1, lp["ln2"], cfg.norm_eps)
    if mlp_kind == "moe":
        mlp_out, _ = M.moe_block(lp["moe"], cfg, h)
    else:
        mlp_out = L.gated_mlp(h, lp["mlp"]["wi_gate"], lp["mlp"]["wi_up"],
                              lp["mlp"]["wo"])
    if cfg.post_norm:
        mlp_out = L.rms_norm(mlp_out, lp["post_ln2"], cfg.norm_eps)
    return x1 + mlp_out, cache


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    keys = L.split_keys(key, 8)
    segments = build_segments(cfg)
    p: dict = {"final_norm": jnp.zeros((cfg.d_model,), dtype)}

    if cfg.modality == "audio_tokens":
        p["codebook_embed"] = L.dense_init(
            keys[0], (cfg.n_codebooks, cfg.vocab, cfg.d_model),
            in_axis=2, dtype=dtype)
        p["codebook_head"] = L.dense_init(
            keys[1], (cfg.n_codebooks, cfg.d_model, cfg.vocab),
            in_axis=1, dtype=dtype)
    else:
        p["embed"] = L.dense_init(
            keys[0], (cfg.vocab, cfg.d_model), in_axis=1, dtype=dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = L.dense_init(
                keys[1], (cfg.d_model, cfg.vocab), dtype=dtype)
    if cfg.modality == "vision_text":
        p["vision_proj"] = {
            "w1": L.dense_init(keys[2], (cfg.vision_dim, cfg.d_model),
                               dtype=dtype),
            "w2": L.dense_init(keys[3], (cfg.d_model, cfg.d_model),
                               dtype=dtype),
        }
    if any(k == "shared_attn" for k in cfg.layer_kinds()):
        p["shared_attn"] = _init_shared_attn(keys[4], cfg, dtype)

    seg_params = []
    kseg = keys[5]
    for si, (desc, n_rep) in enumerate(segments):
        pos_params = []
        for pi, (kind, mlp_kind) in enumerate(desc):
            kpos = jax.random.fold_in(jax.random.fold_in(kseg, si), pi)
            stacked = jax.vmap(
                lambda kk: _init_layer(kk, cfg, kind, mlp_kind, dtype)
            )(jax.random.split(kpos, n_rep))
            pos_params.append(stacked)
        seg_params.append(pos_params)
    p["segments"] = seg_params
    return p


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ArchConfig, batch, act_dtype=jnp.bfloat16):
    """batch -> (x (B,S,D), positions (S,))."""
    if cfg.modality == "audio_tokens":
        toks = batch["tokens"]                              # (B,S,ncb)
        emb = params["codebook_embed"]                      # (ncb,V,D)
        x = jnp.zeros((*toks.shape[:2], cfg.d_model), act_dtype)
        for cb in range(cfg.n_codebooks):
            x = x + emb[cb].astype(act_dtype)[toks[..., cb]]
    elif cfg.modality == "vision_text":
        toks = batch["tokens"]                              # (B,S_text)
        patches = batch["patch_embeds"]                     # (B,P,vd)
        vp = params["vision_proj"]
        pe = jnp.einsum("bpv,vd->bpd", patches.astype(act_dtype),
                        vp["w1"].astype(act_dtype))
        pe = jnp.einsum("bpd,de->bpe", jax.nn.gelu(pe),
                        vp["w2"].astype(act_dtype))
        te = params["embed"].astype(act_dtype)[toks]
        x = jnp.concatenate([pe, te], axis=1)
    else:
        x = params["embed"].astype(act_dtype)[batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, act_dtype)
    positions = jnp.arange(x.shape[1])
    return x, positions


def lm_logits(params, cfg: ArchConfig, x):
    x32 = x.astype(jnp.float32)
    if cfg.modality == "audio_tokens":
        logits = jnp.einsum("bsd,cdv->bscv", x32,
                            params["codebook_head"].astype(jnp.float32))
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x32,
                            params["embed"].astype(jnp.float32))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x32,
                            params["lm_head"].astype(jnp.float32))
    return L.softcap(logits, cfg.logit_softcap)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

_REMAT_POLICIES = {
    "full": None,  # recompute everything inside the group
    "dots": None,  # filled lazily: save matmul outputs, recompute the rest
}


def _remat_policy(name: str):
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


def forward(params, cfg: ArchConfig, batch, *, act_dtype=jnp.bfloat16,
            return_cache: bool = False, remat: bool = True,
            return_hidden: bool = False, scan_unroll: bool = False,
            remat_policy: str = "full"):
    """Full-sequence forward. Returns (logits|hidden, aux[, cache]).

    scan_unroll fully unrolls the layer-group scans — used by the dry-run
    cost probes, because XLA's cost_analysis counts a while-loop body once
    regardless of trip count.
    """
    segments = build_segments(cfg)
    x, positions = embed_inputs(params, cfg, batch, act_dtype)
    bpos = jnp.broadcast_to(positions[None, :], x.shape[:2])
    shared_p = params.get("shared_attn")
    aux = jnp.zeros((), jnp.float32)
    caches = []

    # Under the FSDP policy, weights live sharded over the data axes; the
    # all-gather must happen PER SCAN ITERATION (one layer group live at a
    # time), not hoisted above the scan (which would materialize the whole
    # gathered stack — measured 142 GiB of transients on qwen3-235b).  A
    # TP-only sharding constraint inside the body (model axis kept, data
    # axes dropped) forces the per-iteration gather.
    from repro.distributed import hints as _H
    _hints = _H.get_hints()
    _fsdp = _hints is not None and _hints.fsdp

    def _slice_gatherer(pos_params):
        if not _fsdp:
            return lambda tree: tree
        from jax.lax import with_sharding_constraint as _wsc
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.distributed.sharding import _param_spec, _path_str
        mesh = _hints.mesh

        def spec_of(path, leaf):
            ps = "segments/" + _path_str(path)
            dims = list(_param_spec(ps, leaf.shape, mesh, stacked=True))
            dims += [None] * (leaf.ndim - len(dims))
            return NamedSharding(mesh, PartitionSpec(*dims[1:]))

        specs = jax.tree_util.tree_map_with_path(spec_of, pos_params)
        return lambda tree: jax.tree.map(_wsc, tree, specs)

    for (desc, n_rep), pos_params in zip(segments, params["segments"]):
        _gather_slice = _slice_gatherer(pos_params)

        def group_body(carry, group_params, desc=desc,
                       _gather_slice=_gather_slice):
            x, aux = carry
            group_params = _gather_slice(group_params)
            entries = []
            for pi, (kind, mlp_kind) in enumerate(desc):
                x, cache_e, aux = _apply_layer(
                    group_params[pi], shared_p, cfg, kind, mlp_kind,
                    x, bpos, aux)
                entries.append(cache_e if return_cache else None)
            return (x, aux), entries

        if remat:
            body = jax.checkpoint(group_body,
                                  policy=_remat_policy(remat_policy))
        else:
            body = group_body
        (x, aux), seg_cache = jax.lax.scan(body, (x, aux), pos_params,
                                           unroll=scan_unroll)
        caches.append(seg_cache)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    out = x if return_hidden else lm_logits(params, cfg, x)
    if return_cache:
        return out, aux, caches
    return out, aux


# ---------------------------------------------------------------------------
# serve: prefill + decode
# ---------------------------------------------------------------------------

def make_cache(cfg: ArchConfig, batch_size: int, s_max: int,
               dtype=jnp.bfloat16):
    """Empty per-segment cache pytree (leading n_rep axis per position)."""
    segments = build_segments(cfg)
    caches = []
    for desc, n_rep in segments:
        entries = []
        for kind, _ in desc:
            if kind == "ssm":
                entries.append({
                    "h": jnp.zeros((n_rep, batch_size, cfg.ssm_heads,
                                    cfg.ssm_head_dim, cfg.ssm_state),
                                   jnp.float32),
                    "conv": jnp.zeros((n_rep, batch_size, cfg.ssm_conv - 1,
                                       cfg.d_inner + 2 * cfg.ssm_state),
                                      dtype),
                })
            else:
                entries.append({
                    "k": jnp.zeros((n_rep, batch_size, s_max,
                                    cfg.n_kv_heads, cfg.head_dim), dtype),
                    "v": jnp.zeros((n_rep, batch_size, s_max,
                                    cfg.n_kv_heads, cfg.head_dim), dtype),
                })
        caches.append(entries)
    return caches


def prefill(params, cfg: ArchConfig, batch, s_max: int | None = None,
            act_dtype=jnp.bfloat16, scan_unroll: bool = False,
            return_hidden: bool = False):
    """Process the prompt; returns (last-position logits, cache, length).

    With `return_hidden=True` a fourth element is appended: the
    post-`final_norm` hidden state of the LAST prompt position, (B, D) —
    the decode-time retrieval query for the first generated token
    (retrieval/knn_lm.py).  The default tuple is unchanged, so
    logits-only callers are untouched.
    """
    hidden, aux, caches = forward(params, cfg, batch, act_dtype=act_dtype,
                                  return_cache=True, remat=False,
                                  return_hidden=True,
                                  scan_unroll=scan_unroll)
    # only the last position's logits are needed — never materialize (B,S,V)
    logits = lm_logits(params, cfg, hidden[:, -1:])
    s = hidden.shape[1]
    if s_max is not None and s_max > s:
        pad = s_max - s

        def pad_kv(c):
            if "k" in c:
                return {
                    "k": jnp.pad(c["k"], ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0))),
                    "v": jnp.pad(c["v"], ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0))),
                }
            return c

        caches = [[pad_kv(e) for e in seg] for seg in caches]
    if return_hidden:
        return logits[:, -1], caches, s, hidden[:, -1]
    return logits[:, -1], caches, s


def decode_step(params, cfg: ArchConfig, caches, tokens, pos,
                batch_extra=None, act_dtype=jnp.bfloat16,
                scan_unroll: bool = False, return_hidden: bool = False):
    """One decode step for every sequence in the batch.

    tokens: (B,) int32 (or (B, ncb) for audio); pos: (B,) current index.
    Returns (logits (B, V) or (B, ncb, V), updated caches).

    With `return_hidden=True` a third element is appended: the
    post-`final_norm` hidden state (B, D) the logits were read from —
    the decode-time retrieval query of retrieval/knn_lm.py.  The default
    two-tuple (and its values) is unchanged: the hidden row is an
    already-computed intermediate, so logits-only callers stay bitwise
    identical.
    """
    segments = build_segments(cfg)
    if cfg.modality == "audio_tokens":
        toks = tokens[:, None, :]                            # (B,1,ncb)
        batch = {"tokens": toks}
    else:
        batch = {"tokens": tokens[:, None]}
        if batch_extra:
            batch.update(batch_extra)
    if cfg.modality == "vision_text":
        # decode is text-only; patches were consumed at prefill
        x = params["embed"].astype(act_dtype)[batch["tokens"]]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, act_dtype)
    else:
        x, _ = embed_inputs(params, cfg, batch, act_dtype)

    shared_p = params.get("shared_attn")
    new_caches = []
    for (desc, n_rep), pos_params, seg_cache in zip(
            segments, params["segments"], caches):

        def group_body(x, xs, desc=desc):
            group_params, group_cache = xs
            new_entries = []
            for pi, (kind, mlp_kind) in enumerate(desc):
                x, cache_e = _apply_layer_decode(
                    group_params[pi], shared_p, cfg, kind, mlp_kind,
                    x, group_cache[pi], pos)
                new_entries.append(cache_e)
            return x, new_entries

        x, new_seg = jax.lax.scan(group_body, x, (pos_params, seg_cache),
                                  unroll=scan_unroll)
        new_caches.append(new_seg)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, cfg, x)
    if return_hidden:
        return logits[:, 0], new_caches, x[:, 0]
    return logits[:, 0], new_caches
