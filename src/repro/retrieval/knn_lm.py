"""kNN-LM: the GRNND index as a first-class serving feature.

A datastore of (hidden-state, next-token) pairs is indexed with the paper's
GRNND graph; at decode time the LM's last hidden state queries the graph,
retrieved neighbors vote on the next token, and the distribution is fused:

    p(y) = (1 - lam) * p_LM(y) + lam * softmax_k(-d_k / tau) [y == y_k]

This is the integration point described in DESIGN.md §4.2: the paper's
contribution (fast graph construction) directly shortens the serving
pipeline's index-build stage.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import grnnd
from repro.core.search import search


class KNNDatastore(NamedTuple):
    keys: jnp.ndarray        # (N, D) hidden states
    values: jnp.ndarray      # (N,) next-token ids
    graph: jnp.ndarray       # (N, R) GRNND adjacency


def build_datastore(key, hidden_states, next_tokens,
                    cfg: grnnd.GRNNDConfig | None = None) -> KNNDatastore:
    """Index (hidden, next-token) pairs with a GRNND graph."""
    cfg = cfg or grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=3,
                                   pairs_per_vertex=24)
    x = hidden_states.astype(jnp.float32)
    pool = grnnd.build_graph(key, x, cfg)
    return KNNDatastore(keys=x, values=next_tokens.astype(jnp.int32),
                        graph=pool.ids)


def knn_logits(store: KNNDatastore, queries: jnp.ndarray, vocab: int,
               *, k: int = 8, ef: int = 32, tau: float = 10.0) -> jnp.ndarray:
    """Retrieve k neighbors per query and form a kNN next-token distribution."""
    res = search(store.keys, store.graph, queries.astype(jnp.float32),
                 k=k, ef=ef)
    w = jax.nn.softmax(-res.dists / tau, axis=-1)          # (Q, k)
    w = jnp.where(res.ids >= 0, w, 0.0)
    toks = store.values[jnp.clip(res.ids, 0)]              # (Q, k)
    probs = jnp.zeros((queries.shape[0], vocab), jnp.float32)
    probs = probs.at[jnp.arange(queries.shape[0])[:, None], toks].add(w)
    denom = jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    return jnp.log(jnp.maximum(probs / denom, 1e-9))


def fuse(lm_logits: jnp.ndarray, knn_log_probs: jnp.ndarray,
         lam: float = 0.25) -> jnp.ndarray:
    """Log-space interpolation of LM and kNN distributions."""
    lm_lp = jax.nn.log_softmax(lm_logits, axis=-1)
    return jnp.logaddexp(lm_lp + jnp.log1p(-lam),
                         knn_log_probs + jnp.log(lam))


def make_logit_hook(store: KNNDatastore, hidden_fn, vocab: int,
                    lam: float = 0.25, **knn_kw):
    """Adapter for ServeEngine(logit_hook=...): fuses retrieval into decode."""
    def hook(lm_logits, hidden):
        klp = knn_logits(store, hidden, vocab, **knn_kw)
        return fuse(lm_logits, klp, lam)
    return hook
