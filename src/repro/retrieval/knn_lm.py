"""kNN-LM: the GRNND index as a first-class serving feature (DESIGN.md §14).

A datastore of (hidden-state, next-token) pairs is indexed with the paper's
GRNND graph; at decode time the LM's post-`final_norm` hidden state queries
the graph, retrieved neighbors vote on the next token, and the distribution
is fused in log space:

    p(y) = (1 - lam) * p_LM(y) + lam * softmax_k(-d_k / tau) [y == y_k]

Two datastore shapes:

  * `KNNDatastore` — the frozen array-backed reference: bare (keys, graph)
    arrays searched via `core.search.search`.  Kept as the parity oracle
    (tests/test_knn_lm.py pins the production path to it bitwise at fp32).
  * `DynamicDatastore` — the production path: a `core.dynamic.DynamicIndex`
    holding the pairs, so the datastore composes every serving subsystem —
    int8/bf16 traversal + fp32 rescore (`DynamicConfig.precision`, §8),
    host-cold rescore placement (`tier="host"`, §13), per-document-source
    filtering (vertex labels, §9), decode-time streaming inserts (the §7
    dynamic workload, for real), and optionally the continuous-batching
    `serve.ann_engine.AnnEngine` scheduler (§12) so retrieval latency rides
    the same queue as every other ANN request.

The kNN vote is a NORMALIZED log-distribution with true ``-inf`` support:
tokens no retrieved neighbor voted for carry exactly zero probability, so
`fuse` preserves total mass 1 at any vocab size (the seed's ``log(1e-9)``
clamp leaked ~``lam * vocab * 1e-9`` of extra mass — invisible at toy
vocabs, material at real ones).  A query with no retrieval support at all
(every neighbor slot empty) falls back to the pure LM distribution.

Serving integration: `make_logit_hook` adapts either datastore to
`ServeEngine(logit_hook=)` — the hook receives ``(lm_logits, hidden)`` per
decode step — and `make_stream_hook` adapts a `DynamicDatastore` to
`ServeEngine(token_hook=)`, batching the step's (hidden, sampled-token)
pairs into the index while the generation is still running.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grnnd
from repro.core import pools as P
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.search import search


class KNNDatastore(NamedTuple):
    keys: jnp.ndarray        # (N, D) hidden states
    values: jnp.ndarray      # (N,) next-token ids
    graph: jnp.ndarray       # (N, R) GRNND adjacency


DEFAULT_BUILD_CFG = grnnd.GRNNDConfig(s=12, r=24, t1=3, t2=3,
                                      pairs_per_vertex=24)


def build_datastore(key, hidden_states, next_tokens,
                    cfg: grnnd.GRNNDConfig | None = None) -> KNNDatastore:
    """Index (hidden, next-token) pairs with a GRNND graph (array-backed)."""
    cfg = cfg or DEFAULT_BUILD_CFG
    x = hidden_states.astype(jnp.float32)
    pool = grnnd.build_graph(key, x, cfg)
    return KNNDatastore(keys=x, values=next_tokens.astype(jnp.int32),
                        graph=pool.ids)


def vote_log_probs(ids, dists, toks, vocab: int,
                   tau: float = 10.0) -> jnp.ndarray:
    """Neighbor vote -> normalized next-token log-distribution.

    ids (Q, k) mark valid neighbor slots (>= 0); dists (Q, k) are their
    squared distances; toks (Q, k) their stored next-tokens.  Weights are
    softmax(-d/tau) over the valid slots, scatter-added per token.  The
    result is a true log-distribution: unvoted tokens are ``-inf`` (NOT a
    clamp — `fuse` needs exact zeros to preserve mass), voted rows are
    logsumexp-normalized, and a row with no valid slot at all is all-
    ``-inf`` (fuse's pure-LM fallback).  Shared by the array-backed and
    DynamicIndex-backed paths so their outputs are comparable bitwise.
    """
    w = jax.nn.softmax(-dists / tau, axis=-1)              # (Q, k)
    w = jnp.where(ids >= 0, w, 0.0)
    q = ids.shape[0]
    probs = jnp.zeros((q, vocab), jnp.float32)
    probs = probs.at[jnp.arange(q)[:, None], toks].add(w)
    logp = jnp.where(probs > 0, jnp.log(probs), -jnp.inf)
    lse = jax.nn.logsumexp(logp, axis=-1, keepdims=True)
    return jnp.where(jnp.isfinite(lse), logp - lse, -jnp.inf)


def knn_logits(store: KNNDatastore, queries: jnp.ndarray, vocab: int,
               *, k: int = 8, ef: int = 32, tau: float = 10.0,
               **search_kw) -> jnp.ndarray:
    """Retrieve k neighbors per query and form the kNN log-distribution.

    Extra keywords pass through to `core.search.search` (entry=, valid=,
    visited=, ...) — the parity tier uses them to pin this reference path
    to a `DynamicDatastore`'s exact traversal.
    """
    res = search(store.keys, store.graph, queries.astype(jnp.float32),
                 k=k, ef=ef, **search_kw)
    toks = store.values[jnp.clip(res.ids, 0)]              # (Q, k)
    return vote_log_probs(res.ids, res.dists, toks, vocab, tau)


def fuse(lm_logits: jnp.ndarray, knn_log_probs: jnp.ndarray,
         lam: float = 0.25) -> jnp.ndarray:
    """Log-space interpolation of LM and kNN distributions.

    `knn_log_probs` must be a normalized log-distribution whose
    unsupported tokens are exactly ``-inf`` (`vote_log_probs`); then the
    fused mass is exactly (1-lam) + lam = 1 at ANY vocab size.  Rows with
    no retrieval support at all (all ``-inf``) fall back to the pure LM
    distribution instead of silently renormalizing to mass (1-lam).
    """
    lm_lp = jax.nn.log_softmax(lm_logits, axis=-1)
    fused = jnp.logaddexp(lm_lp + jnp.log1p(-lam),
                          knn_log_probs + jnp.log(lam))
    has_support = jnp.isfinite(
        jax.nn.logsumexp(knn_log_probs, axis=-1, keepdims=True))
    return jnp.where(has_support, fused, lm_lp)


class DynamicDatastore:
    """A kNN-LM datastore on the production index stack.

    Wraps a `DynamicIndex` over the (hidden -> next-token) pairs plus the
    label-indexed token table: the index issues a monotone external label
    per inserted row (stable across compaction and layout renumbering),
    so ``values[label]`` is the token lookup and survives any internal
    slot movement.  `add` streams new pairs in during decode (batched
    insert -> localized refinement, DESIGN.md §7); `knn_log_probs` routes
    every query through the fused `search_expand` kernels — int8/bf16
    traversal with fp32 rescore when `precision` says so, host-cold
    rescore under `tier="host"`, and per-document-source predicates via
    `sources=`/`filter=` (§9).

    `attach_engine()` swaps the direct `index.search` call for the
    continuous-batching `AnnEngine` (§12): queries and the streaming
    inserts ride the same bounded queue, so retrieval latency is measured
    (and shaped) by the same scheduler as any other ANN traffic —
    `engine.stats()` then reports p50/p99 per decode-step retrieval.
    """

    def __init__(self, index: DynamicIndex, values: np.ndarray,
                 vocab: int, *, k: int = 8, ef: int = 32, tau: float = 10.0):
        values = np.asarray(values, np.int32)
        assert values.shape == (index._next_label,), \
            "need one stored token per issued label"
        self.index = index
        self.vocab = int(vocab)
        self.k, self.ef, self.tau = int(k), int(ef), float(tau)
        self._values = values
        self._engine = None

    # -- construction -----------------------------------------------------

    @classmethod
    def build(cls, key, hidden_states, next_tokens, vocab: int, *,
              build_cfg: grnnd.GRNNDConfig | None = None,
              precision: str = "int8", tier: str = "device",
              sources=None, n_sources: int | None = None,
              dyn_cfg: DynamicConfig | None = None,
              **knn_kw) -> "DynamicDatastore":
        """GRNND-build the initial corpus, then wrap it mutably.

        `sources` tags each pair with an int document-source label in
        ``[0, n_sources)``; queries can then restrict retrieval to a
        source subset with ``filter=`` (provenance-scoped retrieval).
        """
        x = jnp.asarray(hidden_states, jnp.float32)
        cfg = build_cfg or DEFAULT_BUILD_CFG
        dyn = (dyn_cfg or DynamicConfig())._replace(
            precision=precision, tier=tier)
        pool = grnnd.build_graph(key, x, cfg)
        index = DynamicIndex(x, pool, dyn, vertex_labels=sources,
                             n_labels=n_sources)
        return cls(index, np.asarray(next_tokens, np.int32), vocab, **knn_kw)

    @classmethod
    def empty(cls, dim: int, vocab: int, *, r: int = 16,
              precision: str = "int8", tier: str = "device",
              n_sources: int | None = None,
              dyn_cfg: DynamicConfig | None = None,
              **knn_kw) -> "DynamicDatastore":
        """A zero-entry datastore that exists purely to be streamed into
        (the first `add` bootstraps the graph off its own batch)."""
        dyn = (dyn_cfg or DynamicConfig())._replace(
            precision=precision, tier=tier)
        pool = P.Pool(jnp.zeros((0, r), jnp.int32),
                      jnp.zeros((0, r), jnp.float32))
        sources = None if n_sources is None else np.zeros((0,), np.int32)
        index = DynamicIndex(jnp.zeros((0, dim), jnp.float32), pool, dyn,
                             vertex_labels=sources, n_labels=n_sources)
        return cls(index, np.zeros((0,), np.int32), vocab, **knn_kw)

    def __len__(self) -> int:
        return len(self.index)

    # -- serving ----------------------------------------------------------

    def attach_engine(self, cfg=None, **engine_kw):
        """Route queries and streaming inserts through an `AnnEngine`
        (continuous batching, admission control, mutation interleave);
        returns the engine so callers can read `stats()`."""
        from repro.serve.ann_engine import AnnEngine, DynamicWorker, \
            EngineConfig
        if cfg is None:
            cfg = EngineConfig(ef_menu=(self.ef,),
                               k_cap=max(16, self.k))
        self._engine = AnnEngine(DynamicWorker(self.index), cfg, **engine_kw)
        return self._engine

    def add(self, hidden_states, next_tokens, sources=None) -> np.ndarray:
        """Insert a batch of (hidden, next-token) pairs; returns labels.

        The decode-time streaming path: batched insert + localized
        refinement keeps the graph searchable between steps, and tokens
        written here are retrievable by the SAME generation's later steps
        (tests/test_knn_lm.py).  With an attached engine the insert rides
        the mutation queue (drained before returning, so the label/value
        bookkeeping stays aligned with execution order).
        """
        xs = jnp.asarray(hidden_states, jnp.float32)
        toks = np.asarray(next_tokens, np.int32).reshape(-1)
        assert xs.shape[0] == toks.shape[0]
        if self._engine is not None:
            self._engine.submit_insert(np.asarray(xs), labels=sources)
            self._engine.run()
            # labels are issued monotonically at insert EXECUTION; the
            # drained queue guarantees this batch got the latest block
            labels = np.arange(self.index._next_label - len(toks),
                               self.index._next_label, dtype=np.int64)
        else:
            labels = self.index.insert(xs, vertex_labels=sources)
        self._values = np.concatenate([self._values, toks])
        assert self._values.shape == (self.index._next_label,)
        return labels

    def _search(self, queries, *, k: int, ef: int, filter=None):
        if self._engine is None:
            res = self.index.search(queries, k=k, ef=ef, filter=filter)
            return res.ids, res.dists
        fw = (None if filter is None
              else np.asarray(self.index._query_words(filter)))
        qn = np.asarray(queries, np.float32)
        rids = [self._engine.submit(
            qn[i], k=k, ef=ef,
            filter_words=None if fw is None else fw[i])
            for i in range(qn.shape[0])]
        self._engine.run()
        done = [self._engine.take_result(r) for r in rids]
        return (jnp.asarray(np.stack([r.ids for r in done])),
                jnp.asarray(np.stack([r.dists for r in done])))

    def knn_log_probs(self, queries, *, k: int | None = None,
                      ef: int | None = None, tau: float | None = None,
                      filter=None) -> jnp.ndarray:
        """Retrieve + vote: the production counterpart of `knn_logits`.

        `filter` restricts retrieval to matching document sources
        (core/labels.py query forms; needs a datastore built with
        `sources=`).  An empty datastore has no support anywhere — the
        all-``-inf`` rows make `fuse` serve the pure LM until the first
        `add` lands.
        """
        k = self.k if k is None else k
        ef = self.ef if ef is None else ef
        tau = self.tau if tau is None else tau
        q = jnp.asarray(queries, jnp.float32)
        if len(self) == 0:
            return jnp.full((q.shape[0], self.vocab), -jnp.inf, jnp.float32)
        ids, dists = self._search(q, k=k, ef=ef, filter=filter)
        toks = jnp.asarray(self._values)[jnp.clip(ids, 0)]
        return vote_log_probs(ids, dists, toks, self.vocab, tau)


def make_logit_hook(store, vocab: int | None = None,
                    lam: float = 0.25, **knn_kw):
    """Adapter for `ServeEngine(logit_hook=...)`: fuses retrieval into
    decode.  The hook contract is ``hook(lm_logits, hidden)`` — the engine
    hands over the post-`final_norm` hidden state it read the logits from,
    and the hook queries the datastore with it.  `store` is either
    datastore shape; `vocab` is only needed for the array-backed one.
    """
    dynamic = isinstance(store, DynamicDatastore)
    if not dynamic and vocab is None:
        raise ValueError("array-backed KNNDatastore needs vocab=")

    def hook(lm_logits, hidden):
        q = jnp.asarray(hidden, jnp.float32)
        if dynamic:
            klp = store.knn_log_probs(q, **knn_kw)
        else:
            klp = knn_logits(store, q, vocab, **knn_kw)
        return fuse(lm_logits, klp, lam)
    return hook


def make_stream_hook(store: DynamicDatastore, *, insert_every: int = 8,
                     sources_fn=None):
    """Adapter for `ServeEngine(token_hook=...)`: stream the decode's own
    (hidden, sampled-token) pairs into the datastore DURING generation.

    Pairs are buffered and inserted every `insert_every` steps — equal-
    sized batches at a fixed decode batch, so the insert path's jit caches
    (seed search, staging, localized rounds) stay warm.  `sources_fn(B)`
    optionally labels each step's rows with a document source.  Call
    ``hook.flush()`` after `generate` to commit the tail batch.
    """
    buf_h: list[np.ndarray] = []
    buf_t: list[np.ndarray] = []

    def flush():
        if buf_h:
            h = np.concatenate(buf_h)
            t = np.concatenate(buf_t)
            src = None if sources_fn is None else sources_fn(len(t))
            store.add(h, t, sources=src)
            buf_h.clear()
            buf_t.clear()

    def hook(hidden, tokens):
        buf_h.append(np.asarray(hidden, np.float32))
        buf_t.append(np.asarray(tokens, np.int32).reshape(-1))
        if len(buf_h) >= insert_every:
            flush()

    hook.flush = flush
    return hook
