"""Continuous-batching ANN serving engine (DESIGN.md §12).

`launch/serve.py` historically answered fixed batches in lockstep: every
request waited for the whole batch, and mutations alternated with queries.
Real traffic is a stream of small heterogeneous requests — mixed k/ef/filter
plus online inserts and deletes.  This module turns that stream into the
uniform kernel shapes the fused search path wants, with the scheduler/worker
split an LM serving engine uses (`serve/engine.py` is the in-repo sibling;
the vllm EngineCore split is the architectural exemplar):

* **queue + admission** — `submit()` appends to a FIFO; past
  `EngineConfig.max_pending` the engine sheds load (`EngineSaturated`)
  instead of growing an unbounded backlog.
* **batch shaping** — each step coalesces the head-of-line request with
  every queued request sharing its `(ef, filtered?)` signature, pads the
  stack to the next power-of-two Q bucket, and executes ONE fused search
  call.  Per-query independence of the beam loop makes the padding and the
  grouping bitwise-invisible (DESIGN.md §12.2) — engine results equal the
  direct `core/search` call for the same request, locked by
  tests/test_ann_engine.py on both CI backend legs.
* **bounded recompilation** — jit traces key on (Q bucket, ef, filtered, k
  slice); Q buckets are powers of two, ef is normalized against
  `EngineConfig.ef_menu` at admission, and every batch executes at the
  fixed `min(k_cap, ef)` result width then slices per request — the trace
  count is bounded by |buckets| x |menu| x 2 regardless of the request mix.
* **mutation interleave** — mutations run BETWEEN query batches under a
  quantum policy (one mutation drain per `query_quantum` query batches
  while both queues are backed up), not in lockstep with them.
* **stats** — nearest-rank p50/p99 latency, QPS, mutations/sec, batch
  occupancy, per-bucket execution counts.  The clock is injectable and the
  worker is a three-method protocol, so every scheduling decision is
  deterministic and testable on CPU with a fake worker.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Callable, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import labels as L
from repro.core.search import medoid, search


class EngineSaturated(RuntimeError):
    """Admission control rejected the request (queue at max_pending)."""


class EngineConfig(NamedTuple):
    """Scheduler knobs.  Defaults suit the reproduction-scale CPU runs.

    `ef_menu` bounds recompilation: an admitted ef is rounded UP to the
    smallest menu entry (raising ef only improves recall); values beyond
    the menu are served exactly, each costing one extra trace.  An empty
    menu serves every requested ef exactly.  `k_cap` is the fixed result
    width batches execute at (requests slice their own k from it); k only
    slices the final merged list, so the slice is bitwise-identical to a
    direct call at the same ef (DESIGN.md §12.2).
    """

    max_pending: int = 1024
    max_batch: int = 64
    query_quantum: int = 4
    overfetch: int = 4
    ef_menu: tuple = (32, 48, 64, 96, 128)
    k_cap: int = 16


@dataclasses.dataclass
class QueryRequest:
    rid: int
    vector: np.ndarray
    k: int
    ef: int  # admission-normalized (menu + filtered over-fetch applied)
    fwords: np.ndarray | None
    t_submit: float


@dataclasses.dataclass
class MutationRequest:
    kind: str  # "insert" | "delete" | "delete_oldest"
    n_items: int
    vectors: np.ndarray | None = None
    labels: np.ndarray | None = None
    t_submit: float = 0.0


class QueryResult(NamedTuple):
    ids: np.ndarray  # (k,) int32 — row ids (static) or external labels (dynamic)
    dists: np.ndarray  # (k,) float32
    t_submit: float
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


class EngineStats(NamedTuple):
    n_completed: int
    n_rejected: int
    n_mutations: int  # individual vectors inserted/deleted, not requests
    p50_ms: float
    p99_ms: float
    qps: float
    mutations_per_sec: float
    mean_occupancy: float  # real rows / padded bucket rows, mean over batches
    n_buckets: int  # distinct (Q bucket, ef, filtered) shapes executed
    bucket_runs: dict  # (qb, ef, filtered) -> executed batch count


def percentile(values, p: float) -> float:
    """Nearest-rank percentile: sorted[ceil(p/100 * n) - 1], clamped.

    The rule is fixed (not interpolated) so hand-computed traces in the
    test tier stay exact: p50 of [1, 2, 3, 4] is 2, p99 is 4.
    """
    if not values:
        return 0.0
    xs = sorted(values)
    i = max(0, min(len(xs) - 1, math.ceil(p / 100.0 * len(xs)) - 1))
    return xs[i]


def bucket_q(n: int) -> int:
    """Next power-of-two Q bucket (>= 1) for a batch of n real requests."""
    return 1 << max(0, (n - 1).bit_length())


def normalize_ef(cfg: EngineConfig, k: int, ef: int, filtered: bool) -> int:
    """Admission-time ef: the §9.3 over-fetch floor for filtered requests
    (mirroring what a direct `core.search` call would apply internally),
    then the menu round-up.  The worker executes at this value with
    overfetch=1, so the compiled program matches a direct call whose
    effective ef lands on the same number."""
    if filtered:
        ef = max(ef, cfg.overfetch * k)
    for m in cfg.ef_menu:
        if m >= ef:
            return m
    return ef


class AnnEngine:
    """Request queue + dynamic batch-shaping scheduler + worker driver.

    `worker` implements the three-method protocol below (`StaticWorker`,
    `DynamicWorker`, `ShardedWorker`, or a test fake); `clock` is any
    zero-arg float callable (injectable for deterministic tests).
    """

    def __init__(
        self,
        worker,
        cfg: EngineConfig = EngineConfig(),
        *,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.worker = worker
        self.cfg = cfg
        self.clock = clock
        self._queries: deque[QueryRequest] = deque()
        self._mutations: deque[MutationRequest] = deque()
        self._results: dict[int, QueryResult] = {}
        self._next_rid = 0
        self._since_mut = 0
        # buckets survive reset_stats(): jit caches do not reset either
        self._buckets_seen: dict = {}
        self.reset_stats()

    # ------------------------------------------------------------- admission

    def submit(self, vector, *, k: int = 10, ef: int = 64, filter_words=None) -> int:
        """Admit one query; returns its request id.

        Raises EngineSaturated (and counts the rejection) past
        `max_pending`.  `filter_words` is the (W,) packed predicate for
        this request (core/labels.py), or None for unfiltered.
        """
        if not 1 <= k <= min(self.cfg.k_cap, ef):
            raise ValueError(f"need 1 <= k <= min(k_cap={self.cfg.k_cap}, ef={ef}); got k={k}")
        if len(self._queries) >= self.cfg.max_pending:
            self.n_rejected += 1
            raise EngineSaturated(f"query queue at max_pending={self.cfg.max_pending}")
        filtered = filter_words is not None
        ef = normalize_ef(self.cfg, k, ef, filtered)
        rid = self._next_rid
        self._next_rid += 1
        t = self.clock()
        if self._t_first_submit is None:
            self._t_first_submit = t
        self._queries.append(
            QueryRequest(
                rid=rid,
                vector=np.asarray(vector, np.float32),
                k=k,
                ef=ef,
                fwords=None if filter_words is None else np.asarray(filter_words, np.int32),
                t_submit=t,
            )
        )
        return rid

    def _submit_mutation(self, mut: MutationRequest) -> None:
        if len(self._mutations) >= self.cfg.max_pending:
            self.n_rejected += 1
            raise EngineSaturated(f"mutation queue at max_pending={self.cfg.max_pending}")
        mut.t_submit = self.clock()
        if self._t_first_submit is None:
            self._t_first_submit = mut.t_submit
        self._mutations.append(mut)

    def submit_insert(self, vectors, labels=None) -> None:
        vectors = np.asarray(vectors, np.float32)
        self._submit_mutation(
            MutationRequest(
                kind="insert",
                n_items=len(vectors),
                vectors=vectors,
                labels=None if labels is None else np.asarray(labels, np.int32),
            )
        )

    def submit_delete(self, labels) -> None:
        labels = np.asarray(labels)
        self._submit_mutation(MutationRequest(kind="delete", n_items=len(labels), labels=labels))

    def submit_delete_oldest(self, n: int) -> None:
        """Delete the n oldest live external labels at EXECUTION time (the
        sliding-window churn workload; labels are assigned by the index at
        insert execution, so a trace cannot know them at submit time)."""
        self._submit_mutation(MutationRequest(kind="delete_oldest", n_items=n))

    # ------------------------------------------------------------ scheduling

    @property
    def pending_queries(self) -> int:
        return len(self._queries)

    @property
    def pending_mutations(self) -> int:
        return len(self._mutations)

    def step(self) -> bool:
        """One scheduling decision: execute one mutation request or one
        shaped query batch.  Returns False when both queues are empty.

        The interleave policy: a pending mutation runs when the query
        queue is empty OR `query_quantum` query batches have run since the
        last mutation — queries cannot starve mutations, mutations cannot
        stall a backed-up query queue for more than one drain.
        """
        if self._mutations and (
            not self._queries or self._since_mut >= self.cfg.query_quantum
        ):
            self._run_mutation()
            return True
        if self._queries:
            self._run_query_batch()
            return True
        return False

    def run(self, max_steps: int | None = None) -> int:
        """Step until idle (or max_steps); returns the steps taken."""
        n = 0
        while (max_steps is None or n < max_steps) and self.step():
            n += 1
        return n

    def take_result(self, rid: int) -> QueryResult:
        return self._results.pop(rid)

    def _run_mutation(self) -> None:
        mut = self._mutations.popleft()
        self.worker.apply_mutation(mut)
        t = self.clock()
        self._t_last_done = t
        self._mut_lat.append(t - mut.t_submit)
        self.n_mutations += mut.n_items
        self._since_mut = 0
        self.log.append(("mutation", mut.kind, mut.n_items))

    def _run_query_batch(self) -> None:
        head = self._queries[0]
        key = (head.ef, head.fwords is not None)
        group: list[QueryRequest] = []
        rest: deque[QueryRequest] = deque()
        while self._queries:
            r = self._queries.popleft()
            if len(group) < self.cfg.max_batch and (r.ef, r.fwords is not None) == key:
                group.append(r)
            else:
                rest.append(r)
        self._queries = rest

        ef, filtered = key
        qb = bucket_q(len(group))
        pad = qb - len(group)
        # pad rows repeat the last real request: per-query independence
        # (§12.2) makes them invisible to the real rows, and a duplicate of
        # real work converges in the same number of beam steps
        q = np.stack([r.vector for r in group] + [group[-1].vector] * pad)
        fw = None
        if filtered:
            fw = np.stack([r.fwords for r in group] + [group[-1].fwords] * pad)
        k_exec = min(self.cfg.k_cap, ef)
        ids, dists = self.worker.search_batch(q, k=k_exec, ef=ef, fwords=fw)
        t = self.clock()
        self._t_last_done = t
        for i, r in enumerate(group):
            self._results[r.rid] = QueryResult(
                ids=np.asarray(ids)[i, : r.k],
                dists=np.asarray(dists)[i, : r.k],
                t_submit=r.t_submit,
                t_done=t,
            )
            self._lat.append(t - r.t_submit)
        self.n_completed += len(group)
        self._occ.append(len(group) / qb)
        bkey = (qb, ef, filtered)
        self._buckets_seen[bkey] = self._buckets_seen.get(bkey, 0) + 1
        self._bucket_runs[bkey] = self._bucket_runs.get(bkey, 0) + 1
        self._since_mut += 1
        self.log.append(("query", bkey, len(group)))

    # ----------------------------------------------------------------- stats

    def reset_stats(self) -> None:
        """Clear the measurement window (e.g. after a compile warm-up
        replay).  The distinct-bucket set survives: jit caches survive too,
        so `n_buckets` keeps meaning 'traces compiled since startup'."""
        self._lat: list[float] = []
        self._mut_lat: list[float] = []
        self._occ: list[float] = []
        self._bucket_runs: dict = {}
        self.n_completed = 0
        self.n_rejected = 0
        self.n_mutations = 0
        self._t_first_submit: float | None = None
        self._t_last_done: float | None = None
        self.log: list[tuple] = []

    def stats(self) -> EngineStats:
        window = 0.0
        if self._t_first_submit is not None and self._t_last_done is not None:
            window = self._t_last_done - self._t_first_submit
        return EngineStats(
            n_completed=self.n_completed,
            n_rejected=self.n_rejected,
            n_mutations=self.n_mutations,
            p50_ms=percentile(self._lat, 50) * 1e3,
            p99_ms=percentile(self._lat, 99) * 1e3,
            qps=self.n_completed / window if window > 0 else 0.0,
            mutations_per_sec=self.n_mutations / window if window > 0 else 0.0,
            mean_occupancy=sum(self._occ) / len(self._occ) if self._occ else 0.0,
            n_buckets=len(self._buckets_seen),
            bucket_runs=dict(self._bucket_runs),
        )


# ------------------------------------------------------------------- workers


class StaticWorker:
    """Executes engine batches through `core.search` over a frozen index.

    Accepts the full serving configuration surface: a VectorStore traversal
    tier + fp32 rescore tier (§8) — device-resident or a host-pinned
    `vecstore.HostTier` (§13; the placement flows through `search`
    untouched, and batching stays bitwise-invisible because the host
    re-rank is per-row like everything else) — a LabelStore for filtered
    requests (§9), an optimized-layout ids_map + permuted entry (§10),
    and the visited-set selection (§6).  Mutations are unsupported by
    construction.
    """

    def __init__(
        self,
        x,
        graph_ids,
        *,
        entry=None,
        visited: str = "dense",
        visited_cap: int | None = None,
        valid=None,
        rescore=None,
        labels=None,
        ids_map=None,
    ):
        self.x = x
        self.graph_ids = graph_ids
        self.entry = entry if entry is not None else medoid(x, valid)
        self.visited = visited
        self.visited_cap = visited_cap
        self.valid = valid
        self.rescore = rescore
        self.vwords = None if labels is None else L.store_words(labels)
        self.ids_map = ids_map

    def search_batch(self, q, *, k: int, ef: int, fwords=None):
        filtered = fwords is not None
        if filtered and self.vwords is None:
            raise ValueError("filtered request against a worker built without labels")
        # overfetch=1: admission already applied the §9.3 policy, so the
        # compiled ef here equals a direct call's effective ef
        res = search(
            self.x,
            self.graph_ids,
            jnp.asarray(q),
            k=k,
            ef=ef,
            entry=self.entry,
            visited=self.visited,
            visited_cap=self.visited_cap,
            valid=self.valid,
            rescore=self.rescore,
            labels=self.vwords if filtered else None,
            filter=jnp.asarray(fwords) if filtered else None,
            overfetch=1,
            ids_map=self.ids_map,
        )
        return np.asarray(res.ids), np.asarray(res.dists)

    def apply_mutation(self, mut: MutationRequest) -> None:
        raise RuntimeError("StaticWorker serves a frozen index; use DynamicWorker")


class DynamicWorker:
    """Executes engine batches through a `core.dynamic.DynamicIndex` —
    queries return EXTERNAL LABELS, and insert/delete/delete_oldest
    mutations apply to the live index between query batches."""

    def __init__(self, index, *, visited: str = "dense", visited_cap: int | None = None):
        self.index = index
        self.visited = visited
        self.visited_cap = visited_cap

    def search_batch(self, q, *, k: int, ef: int, fwords=None):
        res = self.index.search(
            jnp.asarray(q),
            k=k,
            ef=ef,
            visited=self.visited,
            visited_cap=self.visited_cap,
            filter=None if fwords is None else jnp.asarray(fwords),
            overfetch=1,
        )
        return np.asarray(res.ids), np.asarray(res.dists)

    def apply_mutation(self, mut: MutationRequest) -> None:
        idx = self.index
        if mut.kind == "insert":
            idx.insert(jnp.asarray(mut.vectors), vertex_labels=mut.labels)
        elif mut.kind == "delete":
            idx.delete(np.asarray(mut.labels))
        elif mut.kind == "delete_oldest":
            live = idx.labels[: idx.size][np.asarray(idx.valid[: idx.size])]
            idx.delete(np.sort(live)[: mut.n_items])
        else:
            raise ValueError(f"unknown mutation kind {mut.kind!r}")


class ShardedWorker:
    """Executes engine batches through a corpus-sharded index
    (`core.corpus_shard.CorpusShardedIndex`, DESIGN.md §11); results are
    bitwise-identical to the replicated search.  Frozen, like Static."""

    def __init__(self, index, *, mesh=None, visited: str = "dense", visited_cap: int | None = None):
        self.index = index
        self.mesh = mesh
        self.visited = visited
        self.visited_cap = visited_cap

    def search_batch(self, q, *, k: int, ef: int, fwords=None):
        res = self.index.search(
            jnp.asarray(q),
            k=k,
            ef=ef,
            visited=self.visited,
            visited_cap=self.visited_cap,
            filter=None if fwords is None else jnp.asarray(fwords),
            overfetch=1,
            mesh=self.mesh,
        )
        return np.asarray(res.ids), np.asarray(res.dists)

    def apply_mutation(self, mut: MutationRequest) -> None:
        raise RuntimeError("ShardedWorker serves a frozen index; use DynamicWorker")


# --------------------------------------------------------- traces and replay


@dataclasses.dataclass
class TraceEvent:
    t: float  # arrival offset (seconds from trace start)
    kind: str  # "query" | "insert" | "delete_oldest"
    vector: np.ndarray | None = None
    k: int = 10
    ef: int = 48
    fwords: np.ndarray | None = None
    vectors: np.ndarray | None = None  # insert payload
    labels: np.ndarray | None = None
    n: int = 0  # delete_oldest count


def synth_trace(
    rng: np.random.Generator,
    queries: np.ndarray,
    *,
    offered_qps: float,
    k_choices=(10,),
    ef_choices=(48,),
    fwords=None,
    mutation_every: int = 0,
    churn_vectors=None,
    churn_labels=None,
) -> list[TraceEvent]:
    """A deterministic open-loop request trace: one query event per row of
    `queries`, Poisson arrivals at `offered_qps`, per-request k/ef drawn
    from the given menus (and the matching `fwords` row when given; a row
    of None makes that request unfiltered, so one trace can mix both).
    With
    `mutation_every` > 0, every that-many queries a churn pair arrives:
    insert the next `churn_vectors` batch + delete_oldest of equal size —
    the sliding-window corpus `--mutable` serving uses."""
    queries = np.asarray(queries, np.float32)
    n = queries.shape[0]
    gaps = rng.exponential(1.0 / offered_qps, size=n)
    ks = rng.choice(np.asarray(k_choices), size=n)
    efs = rng.choice(np.asarray(ef_choices), size=n)
    events: list[TraceEvent] = []
    t = 0.0
    n_churn = 0
    for i in range(n):
        t += gaps[i]
        events.append(
            TraceEvent(
                t=t,
                kind="query",
                vector=queries[i],
                k=int(ks[i]),
                ef=int(efs[i]),
                fwords=(
                    None
                    if fwords is None or fwords[i] is None
                    else np.asarray(fwords[i])
                ),
            )
        )
        if mutation_every and (i + 1) % mutation_every == 0 and churn_vectors is not None:
            vecs = churn_vectors[n_churn % len(churn_vectors)]
            labs = None if churn_labels is None else churn_labels[n_churn % len(churn_labels)]
            n_churn += 1
            events.append(TraceEvent(t=t, kind="insert", vectors=vecs, labels=labs))
            events.append(TraceEvent(t=t, kind="delete_oldest", n=len(vecs)))
    return events


def replay(engine: AnnEngine, trace, *, idle_sleep: float = 2e-4) -> dict[int, int]:
    """Open-loop replay against the engine's own clock: submit each event
    at its arrival offset, stepping the engine while waiting; drain at the
    end.  Saturated submits are shed (the rejection is already counted).
    Returns {trace index -> rid} for admitted queries."""
    rids: dict[int, int] = {}
    t0 = engine.clock()
    for i, ev in enumerate(trace):
        while engine.clock() - t0 < ev.t:
            if not engine.step():
                time.sleep(idle_sleep)
        try:
            if ev.kind == "query":
                rids[i] = engine.submit(ev.vector, k=ev.k, ef=ev.ef, filter_words=ev.fwords)
            elif ev.kind == "insert":
                engine.submit_insert(ev.vectors, labels=ev.labels)
            elif ev.kind == "delete_oldest":
                engine.submit_delete_oldest(ev.n)
            else:
                raise ValueError(f"unknown trace event kind {ev.kind!r}")
        except EngineSaturated:
            pass
    engine.run()
    return rids
