"""Batched serving engine: prefill + decode loop with KV/SSM caches.

A deliberately small but real engine: fixed-batch continuous decoding with
greedy/temperature sampling, per-sequence stop handling, and an optional
GRNND-backed kNN-LM fusion hook (retrieval/knn_lm.py).  The step functions
are jit-compiled once per (batch, s_max) bucket.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, s_max: int,
                 act_dtype=jnp.bfloat16,
                 logit_hook: Callable | None = None):
        self.cfg = cfg
        self.params = params
        self.s_max = s_max
        self.act_dtype = act_dtype
        self.logit_hook = logit_hook

        self._prefill = jax.jit(self._prefill_impl)
        # donate the caches: decode updates them in place (no per-step copy
        # of the multi-GiB KV buffers)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))

    def _prefill_impl(self, params, batch):
        logits, caches, plen = T.prefill(
            params, self.cfg, batch, s_max=self.s_max,
            act_dtype=self.act_dtype)
        return logits, caches, plen

    def _decode_impl(self, params, caches, tokens, pos, key):
        logits, caches = T.decode_step(params, self.cfg, caches, tokens, pos,
                                       act_dtype=self.act_dtype)
        return logits, caches

    @staticmethod
    def _sample(key, logits, temperature: float):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch, *, max_new_tokens: int, temperature: float = 0.0,
                 key=None, eos_id: int | None = None,
                 return_hidden: bool = False):
        """Prefill the prompt batch, then decode greedily/sampled.

        Returns dict with tokens (B, max_new_tokens) and per-step logits
        summaries.
        """
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        logits, caches, plen = self._prefill(self.params, batch)
        b = logits.shape[0]
        pos = jnp.full((b,), plen, jnp.int32)
        done = jnp.zeros((b,), bool)

        outs = []
        for step in range(max_new_tokens):
            key, k_s = jax.random.split(key)
            if self.logit_hook is not None:
                logits = self.logit_hook(logits)
            tok = self._sample(k_s, logits, temperature)
            if cfg.modality != "audio_tokens" and eos_id is not None:
                done = done | (tok == eos_id)
                tok = jnp.where(done, eos_id, tok)
            outs.append(tok)
            logits, caches = self._decode(self.params, caches, tok, pos, k_s)
            pos = pos + 1
            if eos_id is not None and bool(jnp.all(done)):
                break

        return {
            "tokens": jnp.stack(outs, axis=1),
            "final_pos": pos,
        }
