"""Batched serving engine: prefill + decode loop with KV/SSM caches.

A deliberately small but real engine: fixed-batch continuous decoding with
greedy/temperature sampling, per-sequence stop handling, and the two hooks
that make retrieval-in-the-loop (retrieval/knn_lm.py, DESIGN.md §14) a
first-class serving feature:

  * ``logit_hook(lm_logits, hidden) -> logits`` runs BEFORE sampling each
    step.  ``hidden`` is the post-`final_norm` hidden state the logits were
    read from — the decode-time retrieval query.  The two-argument contract
    is load-bearing: kNN-LM fusion needs the query vector, not just the
    distribution (tests/test_knn_lm.py locks a real `make_logit_hook`
    through `generate`).
  * ``token_hook(hidden, tokens)`` runs AFTER sampling each step with the
    same hidden state and the tokens it produced — the (key, value) pair a
    streaming kNN-LM datastore inserts during decode
    (`knn_lm.make_stream_hook`).

The step functions are jit-compiled once per (batch, s_max) bucket.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, s_max: int,
                 act_dtype=jnp.bfloat16,
                 logit_hook: Callable | None = None,
                 token_hook: Callable | None = None):
        self.cfg = cfg
        self.params = params
        self.s_max = s_max
        self.act_dtype = act_dtype
        self.logit_hook = logit_hook
        self.token_hook = token_hook

        self._prefill = jax.jit(self._prefill_impl)
        # donate the caches: decode updates them in place (no per-step copy
        # of the multi-GiB KV buffers)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))

    def _prefill_impl(self, params, batch):
        logits, caches, plen, hidden = T.prefill(
            params, self.cfg, batch, s_max=self.s_max,
            act_dtype=self.act_dtype, return_hidden=True)
        return logits, caches, plen, hidden

    def _decode_impl(self, params, caches, tokens, pos):
        logits, caches, hidden = T.decode_step(
            params, self.cfg, caches, tokens, pos,
            act_dtype=self.act_dtype, return_hidden=True)
        return logits, caches, hidden

    @staticmethod
    def _sample(key, logits, temperature: float):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch, *, max_new_tokens: int, temperature: float = 0.0,
                 key=None, eos_id: int | None = None,
                 return_hidden: bool = False):
        """Prefill the prompt batch, then decode greedily/sampled.

        Returns a dict with ``tokens`` (B, T) and ``final_pos`` (B,); with
        ``return_hidden=True`` also ``hidden`` (B, T, D) — per step, the
        post-`final_norm` state its token was sampled from (``hidden[:, t]``
        is the retrieval key whose "next token" is ``tokens[:, t]``, the
        exact pair a kNN-LM datastore stores).
        """
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        logits, caches, plen, hidden = self._prefill(self.params, batch)
        b = logits.shape[0]
        pos = jnp.full((b,), plen, jnp.int32)
        done = jnp.zeros((b,), bool)

        outs = []
        hiddens = []
        for step in range(max_new_tokens):
            key, k_s = jax.random.split(key)
            if self.logit_hook is not None:
                logits = self.logit_hook(logits, hidden)
            tok = self._sample(k_s, logits, temperature)
            if cfg.modality != "audio_tokens" and eos_id is not None:
                done = done | (tok == eos_id)
                tok = jnp.where(done, eos_id, tok)
            outs.append(tok)
            if return_hidden:
                hiddens.append(hidden)
            if self.token_hook is not None:
                self.token_hook(hidden, tok)
            logits, caches, hidden = self._decode(self.params, caches, tok,
                                                  pos)
            pos = pos + 1
            if eos_id is not None and bool(jnp.all(done)):
                break

        out = {
            "tokens": jnp.stack(outs, axis=1),
            "final_pos": pos,
        }
        if return_hidden:
            out["hidden"] = jnp.stack(hiddens, axis=1)
        return out
