"""AdamW with decoupled weight decay — sharding-friendly pytree states.

Optimizer states mirror parameter sharding exactly (same pytree structure,
same leading axes), so pjit shards them with the identical rules and no
extra annotation.  Includes global-norm clipping and a cosine schedule.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: any
    nu: any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros_like(p)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def apply(cfg: AdamWConfig, state: AdamWState, params, grads):
    """One AdamW update. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                            + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
