"""Loss + train step: CE over next-token targets, microbatch gradient
accumulation, optional int8 gradient compression for the cross-pod (DCN)
reduction, MoE aux-loss folding.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.train import optimizer as O


def _targets_from(batch, cfg: ArchConfig):
    toks = batch["tokens"]
    if cfg.modality == "vision_text":
        # loss only on text positions; logits cover [patches | text]
        return toks
    return toks


def _ce_from_hidden(params, cfg: ArchConfig, hidden, targets,
                    chunk: int = 512):
    """Sequence-chunked cross-entropy: the (B, S, V) logits tensor is never
    materialized (68 GB/device for a 262k vocab at 4k seq) — each scan step
    computes one S-chunk of logits in fp32, reduces to a scalar, and the
    remat'd backward recomputes it.
    """
    b, s = hidden.shape[:2]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad)) + ((0, 0),) *
                         (hidden.ndim - 2))
        targets = jnp.pad(targets, ((0, 0), (0, pad)) + ((0, 0),) *
                          (targets.ndim - 2), constant_values=-1)
    n_chunks = hidden.shape[1] // chunk

    def body(total, i):
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        t = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        logits = T.lm_logits(params, cfg, h)            # fp32, chunk-sized
        lp = jax.nn.log_softmax(logits, axis=-1)
        tc = jnp.clip(t, 0)
        nll = -jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]
        nll = jnp.where(t >= 0, nll, 0.0)
        return total + jnp.sum(nll), None

    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(n_chunks))
    denom = jnp.maximum(jnp.sum((targets >= 0).astype(jnp.float32)), 1.0)
    return total / denom


def loss_fn(params, cfg: ArchConfig, batch, *, aux_weight: float = 0.01,
            act_dtype=jnp.bfloat16, remat: bool = True,
            ce_chunk: int = 512, scan_unroll: bool = False,
            remat_policy: str = "full"):
    """Next-token cross-entropy (mean over predicted positions)."""
    hidden, aux = T.forward(params, cfg, batch, act_dtype=act_dtype,
                            remat=remat, return_hidden=True,
                            scan_unroll=scan_unroll,
                            remat_policy=remat_policy)
    toks = _targets_from(batch, cfg)
    if cfg.modality == "vision_text":
        p = cfg.vision_tokens
        hidden = hidden[:, p:]
    pred_h = hidden[:, :-1]
    tgt = toks[:, 1:]
    ce = _ce_from_hidden(params, cfg, pred_h, tgt, chunk=ce_chunk)
    return ce + aux_weight * aux, {"ce": ce, "moe_aux": aux}


class TrainState(NamedTuple):
    params: any
    opt: O.AdamWState


def make_train_step(cfg: ArchConfig, opt_cfg: O.AdamWConfig, *,
                    microbatches: int = 1, aux_weight: float = 0.01,
                    act_dtype=jnp.bfloat16, compress_pod_grads: bool = False,
                    pod_axis: str | None = None, ce_chunk: int = 512,
                    scan_unroll: bool = False, remat_policy: str = "full"):
    """Build the jit-able train_step(state, batch) -> (state, metrics).

    microbatches > 1 splits the per-device batch and accumulates gradients
    sequentially (activation-memory control).  compress_pod_grads quantizes
    the gradient to int8 for the cross-pod all-reduce (DCN) and dequantizes
    after — see distributed/compression.py.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, aux_weight=aux_weight,
                              act_dtype=act_dtype, ce_chunk=ce_chunk,
                              scan_unroll=scan_unroll,
                              remat_policy=remat_policy), has_aux=True
        )(params)

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mb_i):
                (loss_acc, grads_acc) = carry
                (loss, aux), grads = grads_of(state.params, mb_i)
                grads = jax.tree.map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads), aux

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss_sum, grads), auxs = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_grads), mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            aux = jax.tree.map(lambda a: a[-1], auxs)
        else:
            (loss, aux), grads = grads_of(state.params, batch)

        if compress_pod_grads and pod_axis is not None:
            from repro.distributed.compression import compressed_psum_mean
            grads = jax.tree.map(
                functools.partial(compressed_psum_mean, axis=pod_axis), grads)

        params, opt, om = O.apply(opt_cfg, state.opt, state.params, grads)
        metrics = {"loss": loss, **aux, **om}
        return TrainState(params, opt), metrics

    return train_step
