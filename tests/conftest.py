"""Shared test helpers."""
import pytest


def optional_hypothesis():
    """(given, settings, st) — real hypothesis if installed, else stand-ins
    that mark the decorated tests as skipped.

    hypothesis is a dev-only dependency (requirements-dev.txt); mixed test
    modules use this so their non-property tests still run without it
    (a module-level importorskip would skip the whole file).
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ModuleNotFoundError:
        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            def deco(f):
                @pytest.mark.skip(reason="hypothesis not installed")
                def skipped():
                    pass
                skipped.__name__ = f.__name__
                return skipped
            return deco

        def settings(*a, **k):
            return lambda f: f

        return given, settings, _Strategies()
