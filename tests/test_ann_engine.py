"""Continuous-batching ANN engine suite (serve/ann_engine.py, DESIGN.md §12).

Two tiers in one module:

* **scheduler units** — a fake clock + fake worker make every scheduling
  decision deterministic on CPU: bucket selection and padding, admission
  under backlog, mutation-interleave ordering under the quantum policy,
  and the nearest-rank p50/p99 math on a hand-computed latency trace;
* **parity** — the acceptance contract: engine-batched search results are
  BITWISE-identical to direct `core/search` calls for the same request
  set (mixed k/ef/filtered, fp32 and int8+rescore, dense and hashed
  visited, grouped+padded into pow2 buckets), and the dynamic path equals
  a twin DynamicIndex receiving the same mutations directly.

Runs in BOTH CI legs (kernel_parity marker): sizes stay interpret-safe.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grnnd
from repro.core import labels as lab
from repro.core import vecstore
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.pools import Pool
from repro.core.search import medoid, search
from repro.serve.ann_engine import (
    AnnEngine,
    DynamicWorker,
    EngineConfig,
    EngineSaturated,
    StaticWorker,
    bucket_q,
    normalize_ef,
    percentile,
    synth_trace,
)

pytestmark = pytest.mark.kernel_parity

N, D, NL = 192, 16, 16
CFG = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)


# ------------------------------------------------------------------ fakes


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class FakeWorker:
    """Deterministic worker: ids encode the query's first component, so a
    request's result proves which row of which batch served it; each call
    advances the fake clock by `service` seconds."""

    def __init__(self, clock, service=1.0):
        self.clock = clock
        self.service = service
        self.calls = []

    def search_batch(self, q, *, k, ef, fwords=None):
        self.calls.append((q.shape, k, ef, None if fwords is None else fwords.shape))
        self.clock.advance(self.service)
        ids = q[:, 0].astype(np.int32)[:, None] + np.arange(k, dtype=np.int32)
        return ids, ids.astype(np.float32)

    def apply_mutation(self, mut):
        self.clock.advance(self.service)


def fake_engine(**cfg_kw):
    clk = FakeClock()
    w = FakeWorker(clk)
    return AnnEngine(w, EngineConfig(**cfg_kw), clock=clk), w, clk


def vec(tag, d=4):
    v = np.zeros(d, np.float32)
    v[0] = tag
    return v


# -------------------------------------------------------- scheduler units


class TestScheduler:
    def test_bucket_rounding(self):
        assert [bucket_q(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [1, 2, 4, 4, 8, 8, 16]

    def test_bucket_selection_pads_to_pow2(self):
        eng, w, _ = fake_engine(max_batch=8, ef_menu=(48,))
        for i in range(5):
            eng.submit(vec(i), k=5, ef=48)
        eng.run()
        # 5 real requests -> one padded (8, D) batch, occupancy 5/8
        assert w.calls == [((8, 4), 16, 48, None)]
        assert eng.log == [("query", (8, 48, False), 5)]
        assert eng.stats().mean_occupancy == pytest.approx(5 / 8)
        for i in range(5):
            assert eng.take_result(i).ids[0] == i

    def test_grouping_by_ef_preserves_fifo_within_group(self):
        eng, w, _ = fake_engine(max_batch=8, ef_menu=(32, 48))
        order = [32, 48, 32, 48, 48]
        for i, ef in enumerate(order):
            eng.submit(vec(i), k=5, ef=ef)
        eng.run()
        # head-of-line grouping: all ef=32 first (rids 0, 2), then ef=48
        assert eng.log == [("query", (2, 32, False), 2), ("query", (4, 48, False), 3)]
        for i in range(5):
            assert eng.take_result(i).ids[0] == i

    def test_filtered_and_unfiltered_never_share_a_batch(self):
        eng, w, _ = fake_engine(max_batch=8, ef_menu=(48,))
        fw = np.ones(1, np.int32)
        eng.submit(vec(0), k=5, ef=48)
        eng.submit(vec(1), k=5, ef=48, filter_words=fw)
        eng.submit(vec(2), k=5, ef=48)
        eng.run()
        assert [e[1] for e in eng.log] == [(2, 48, False), (1, 48, True)]
        assert w.calls[0][3] is None and w.calls[1][3] == (1, 1)

    def test_admission_rejects_past_max_pending(self):
        eng, _, _ = fake_engine(max_pending=4, max_batch=4, ef_menu=(48,))
        for i in range(4):
            eng.submit(vec(i), k=5, ef=48)
        with pytest.raises(EngineSaturated):
            eng.submit(vec(9), k=5, ef=48)
        assert eng.stats().n_rejected == 1
        eng.run()  # drain frees capacity; admission recovers
        eng.submit(vec(5), k=5, ef=48)
        assert eng.pending_queries == 1

    def test_mutation_interleave_quantum(self):
        # both queues backed up: 2 query batches per mutation drain, and a
        # mutation never waits for the query queue to empty (not lockstep)
        eng, _, _ = fake_engine(max_batch=1, query_quantum=2, ef_menu=(48,))
        for i in range(5):
            eng.submit(vec(i), k=5, ef=48)
        eng.submit_insert(np.zeros((3, 4), np.float32))
        eng.submit_delete(np.arange(2))
        eng.run()
        kinds = [(e[0], e[2] if e[0] == "mutation" else e[1][0]) for e in eng.log]
        assert [e[0] for e in eng.log] == [
            "query",
            "query",
            "mutation",
            "query",
            "query",
            "mutation",
            "query",
        ], kinds
        assert eng.stats().n_mutations == 5  # 3 inserted + 2 deleted items

    def test_mutations_run_immediately_on_idle_queue(self):
        eng, _, _ = fake_engine(query_quantum=4, ef_menu=(48,))
        eng.submit_insert(np.zeros((2, 4), np.float32))
        assert eng.step() and eng.log == [("mutation", "insert", 2)]

    def test_percentile_nearest_rank(self):
        assert percentile([1, 2, 3, 4], 50) == 2
        assert percentile([1, 2, 3, 4], 99) == 4
        assert percentile([7], 50) == 7
        assert percentile([], 99) == 0.0

    def test_stats_on_hand_computed_trace(self):
        # submit at t=0,1,2,3; service 1s; max_batch=1 -> completions at
        # t=4,5,6,7 -> latencies [4,4,4,4]; occupancy 1.0; window 7s
        eng, w, clk = fake_engine(max_batch=1, ef_menu=(48,))
        for i in range(4):
            eng.submit(vec(i), k=5, ef=48)
            clk.advance(1.0)
        eng.run()
        s = eng.stats()
        assert s.n_completed == 4
        assert [eng.take_result(i).latency for i in range(4)] == [5.0, 5.0, 5.0, 5.0]
        assert s.p50_ms == pytest.approx(5000.0) and s.p99_ms == pytest.approx(5000.0)
        assert s.qps == pytest.approx(4 / 8.0)
        assert s.mean_occupancy == 1.0
        assert s.n_buckets == 1 and s.bucket_runs == {(1, 48, False): 4}

    def test_ef_normalization(self):
        cfg = EngineConfig(ef_menu=(32, 64), overfetch=4)
        assert normalize_ef(cfg, 10, 20, False) == 32  # menu round-up
        assert normalize_ef(cfg, 10, 20, True) == 64  # over-fetch floor 40 -> 64
        assert normalize_ef(cfg, 10, 200, False) == 200  # beyond menu: exact
        assert normalize_ef(EngineConfig(ef_menu=()), 10, 20, False) == 20

    def test_reset_stats_keeps_bucket_set(self):
        eng, _, _ = fake_engine(max_batch=4, ef_menu=(48,))
        eng.submit(vec(0), k=5, ef=48)
        eng.run()
        eng.reset_stats()
        s = eng.stats()
        assert s.n_completed == 0 and s.bucket_runs == {}
        assert s.n_buckets == 1  # traces compiled since startup survive

    def test_synth_trace_deterministic_and_interleaved(self):
        rng1, rng2 = np.random.default_rng(7), np.random.default_rng(7)
        q = np.zeros((6, 4), np.float32)
        churn = np.zeros((2, 3, 4), np.float32)
        kw = dict(offered_qps=100.0, k_choices=(5, 10), ef_choices=(32, 48))
        tr1 = synth_trace(rng1, q, mutation_every=3, churn_vectors=churn, **kw)
        tr2 = synth_trace(rng2, q, mutation_every=3, churn_vectors=churn, **kw)
        assert [e.kind for e in tr1] == [
            "query",
            "query",
            "query",
            "insert",
            "delete_oldest",
            "query",
            "query",
            "query",
            "insert",
            "delete_oldest",
        ]
        assert [e.t for e in tr1] == [e.t for e in tr2]
        assert all(a <= b for a, b in zip([e.t for e in tr1], [e.t for e in tr1][1:]))


# ----------------------------------------------------------------- parity


@pytest.fixture(scope="module")
def built():
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32)
    pool = grnnd.build_graph(jax.random.PRNGKey(1), x, CFG)
    vlab = jax.random.randint(jax.random.PRNGKey(5), (N,), 0, NL)
    return x, pool, lab.encode_labels(vlab, NL)


@pytest.fixture(scope="module")
def requests():
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (10, D), jnp.float32))
    fw = np.asarray(lab.random_query_filters(jax.random.PRNGKey(3), 10, NL, 0.4))
    # mixed k/ef/filtered, chosen so the admission-normalized ef equals the
    # requested ef (ef >= overfetch*k and ef in the menu): the direct call
    # below is then literally `search(..., k=k, ef=ef)` on the same numbers.
    # The (32, unfiltered) group gets 5 members -> an (8,)-bucket with 3
    # pad rows, so the padding-invisibility claim is actually exercised.
    specs = [([5, 10][i % 2], [32, 48][(i // 2) % 2], i % 3 == 0) for i in range(10)]
    return q, fw, specs


class TestEngineParity:
    @pytest.mark.parametrize(
        "precision,visited",
        [("fp32", "dense"), ("fp32", "hashed"), ("int8", "dense")],
    )
    def test_static_engine_bitwise_equals_direct(self, built, requests, precision, visited):
        x, pool, ls = built
        q, fw, specs = requests
        xt = x if precision == "fp32" else vecstore.encode(x, precision)
        rescore = None if precision == "fp32" else x
        entry = medoid(xt)
        cap = 4 * N if visited == "hashed" else None
        worker = StaticWorker(
            xt,
            pool.ids,
            entry=entry,
            visited=visited,
            visited_cap=cap,
            rescore=rescore,
            labels=ls,
        )
        eng = AnnEngine(worker, EngineConfig(ef_menu=(32, 48), max_batch=8))
        rids = [
            eng.submit(q[i], k=k, ef=ef, filter_words=fw[i] if filt else None)
            for i, (k, ef, filt) in enumerate(specs)
        ]
        eng.run()
        # grouping + pow2 padding actually happened (not 1-request batches)
        assert any(key[0] > n_real for (_, key, n_real) in eng.log)
        for i, (k, ef, filt) in enumerate(specs):
            res = eng.take_result(rids[i])
            direct = search(
                xt,
                pool.ids,
                jnp.asarray(q[i : i + 1]),
                k=k,
                ef=ef,
                entry=entry,
                visited=visited,
                visited_cap=cap,
                rescore=rescore,
                labels=ls if filt else None,
                filter=jnp.asarray(fw[i : i + 1]) if filt else None,
            )
            np.testing.assert_array_equal(res.ids, np.asarray(direct.ids)[0])
            np.testing.assert_array_equal(res.dists, np.asarray(direct.dists)[0])
            if filt:
                assert lab.predicate_fraction(res.ids[None], fw[i : i + 1], ls.words) == 1.0

    def test_static_engine_equals_one_direct_batched_call(self, built, requests):
        # the other grouping extreme: all 9 requests in ONE direct Q=9 call
        # (same ef/k) must also match — Q-composition invariance end to end
        x, pool, _ = built
        q, _, _ = requests
        entry = medoid(x)
        worker = StaticWorker(x, pool.ids, entry=entry)
        eng = AnnEngine(worker, EngineConfig(ef_menu=(48,), max_batch=4))
        rids = [eng.submit(q[i], k=10, ef=48) for i in range(9)]
        eng.run()
        assert len([e for e in eng.log if e[0] == "query"]) == 3  # 4+4+1
        direct = search(x, pool.ids, jnp.asarray(q), k=10, ef=48, entry=entry)
        for i, rid in enumerate(rids):
            res = eng.take_result(rid)
            np.testing.assert_array_equal(res.ids, np.asarray(direct.ids)[i])
            np.testing.assert_array_equal(res.dists, np.asarray(direct.dists)[i])

    def test_dynamic_engine_matches_twin_index(self, built, requests):
        # engine-scheduled insert -> delete_oldest -> queries equals a twin
        # DynamicIndex receiving the identical mutations directly (label
        # space): mutation routing through the engine is semantics-free
        x, pool, _ = built
        q, _, _ = requests
        cfg = DynamicConfig(refine_rounds=1)
        mk = lambda: DynamicIndex(x, Pool(pool.ids, pool.dists), cfg)  # noqa: E731
        idx_eng, idx_ref = mk(), mk()
        xs = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (8, D), jnp.float32))

        eng = AnnEngine(DynamicWorker(idx_eng), EngineConfig(ef_menu=(48,), max_batch=8))
        eng.submit_insert(xs)
        eng.submit_delete_oldest(4)
        eng.run()  # mutations execute first (empty query queue)
        rids = [eng.submit(q[i], k=10, ef=48) for i in range(9)]
        eng.run()

        idx_ref.insert(jnp.asarray(xs))
        live = idx_ref.labels[: idx_ref.size][np.asarray(idx_ref.valid[: idx_ref.size])]
        idx_ref.delete(np.sort(live)[:4])
        direct = idx_ref.search(jnp.asarray(q), k=10, ef=48, overfetch=1)
        for i, rid in enumerate(rids):
            res = eng.take_result(rid)
            np.testing.assert_array_equal(res.ids, np.asarray(direct.ids)[i])
            np.testing.assert_array_equal(res.dists, np.asarray(direct.dists)[i])
        assert eng.stats().n_mutations == 12
