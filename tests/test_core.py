"""GRNND core behaviour tests: pools, rounds, build quality, search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import grnnd, pools, recall, rnnd_ref
from repro.core.search import search, medoid
from repro.data import synthetic


@pytest.fixture(scope="module")
def small_dataset():
    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 1500)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 150)
    gt = recall.brute_force_knn(x, q, 10)
    return x, q, gt


# ---------------------------------------------------------------------------
# pools
# ---------------------------------------------------------------------------

class TestPools:
    def test_empty_pool_sentinels(self):
        p = pools.empty_pool(7, 5)
        assert p.ids.shape == (7, 5)
        assert bool(jnp.all(p.ids == -1))
        assert bool(jnp.all(jnp.isinf(p.dists)))
        assert bool(jnp.all(p.degree() == 0))

    def test_init_random_no_self_edges(self):
        x = synthetic.make_preset(jax.random.PRNGKey(3), "tiny", 256)
        p = pools.init_random(jax.random.PRNGKey(4), x, s=8, r=16)
        rows = jnp.arange(256)[:, None]
        assert not bool(jnp.any(p.ids == rows))
        # at least one neighbor each; dists are true squared distances
        assert bool(jnp.all(p.degree() >= 1))
        v, s0 = 5, 0
        nid = int(p.ids[v, s0])
        want = float(jnp.sum((x[v] - x[nid]) ** 2))
        np.testing.assert_allclose(float(p.dists[v, s0]), want, rtol=1e-5)

    def test_init_pool_sorted_ascending(self):
        x = synthetic.make_preset(jax.random.PRNGKey(5), "tiny", 128)
        p = pools.init_random(jax.random.PRNGKey(6), x, s=8, r=12)
        d = np.asarray(p.dists)
        d = np.where(np.isinf(d), 1e30, d)
        assert np.all(np.diff(d, axis=1) >= -1e-7)

    def test_group_requests_caps_and_orders(self):
        req = pools.Requests(
            dst=jnp.array([2, 2, 2, 0, -1, 2], jnp.int32),
            src=jnp.array([5, 6, 7, 8, 9, 10], jnp.int32),
            dist=jnp.array([3.0, 1.0, 2.0, 0.5, 0.1, 4.0]),
        )
        ids, dists = pools.group_requests(req, n=4, cap=2)
        # dst=2 received 4 requests; the 2 closest survive, in ascending order
        assert ids[2].tolist() == [6, 7]
        np.testing.assert_allclose(dists[2], [1.0, 2.0])
        assert ids[0].tolist() == [8, -1]
        assert ids[1].tolist() == [-1, -1]
        assert ids[3].tolist() == [-1, -1]

    def test_group_requests_drops_self_inserts(self):
        req = pools.Requests(
            dst=jnp.array([1, 1], jnp.int32),
            src=jnp.array([1, 2], jnp.int32),
            dist=jnp.array([0.0, 1.0]),
        )
        ids, _ = pools.group_requests(req, n=3, cap=2)
        assert ids[1].tolist() == [2, -1]

    def test_insert_requests_respects_capacity_and_dedup(self):
        p = pools.empty_pool(3, 2)
        req = pools.Requests(
            dst=jnp.array([0, 0, 0, 0], jnp.int32),
            src=jnp.array([1, 2, 1, 2], jnp.int32),
            dist=jnp.array([1.0, 2.0, 1.0, 2.0]),
        )
        p2 = pools.insert_requests(p, req)
        assert p2.ids[0].tolist() == [1, 2]
        # closer newcomer evicts the farthest
        req2 = pools.Requests(
            dst=jnp.array([0], jnp.int32), src=jnp.array([5], jnp.int32),
            dist=jnp.array([0.5]))
        p3 = pools.insert_requests(p2, req2)
        assert p3.ids[0].tolist() == [5, 1]


# ---------------------------------------------------------------------------
# build invariants (hypothesis)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(
    n=st.sampled_from([128, 300]),
    r=st.sampled_from([8, 16]),
    order=st.sampled_from(["disordered", "ascending", "descending"]),
    seed=st.integers(0, 1000),
)
def test_build_invariants(n, r, order, seed):
    x = synthetic.vector_dataset(jax.random.PRNGKey(seed), n, 8, n_clusters=8)
    cfg = grnnd.GRNNDConfig(s=min(8, r), r=r, t1=2, t2=2,
                            pairs_per_vertex=8, order=order)
    pool = grnnd.build_graph(jax.random.PRNGKey(seed + 1), x, cfg)
    ids = np.asarray(pool.ids)
    dists = np.asarray(pool.dists)
    rows = np.arange(n)[:, None]
    # no self edges
    assert not np.any(ids == rows)
    # ids in range
    assert np.all(ids < n) and np.all(ids >= -1)
    # per-row uniqueness of valid ids
    for v in range(n):
        valid = ids[v][ids[v] >= 0]
        assert len(valid) == len(set(valid.tolist()))
    # distances correct for valid entries, ascending order, inf for empties
    d = np.where(np.isinf(dists), 1e30, dists)
    assert np.all(np.diff(d, axis=1) >= -1e-6)
    xs = np.asarray(x)
    v = int(np.argmax((ids >= 0).sum(1)))
    for slot in range(r):
        if ids[v, slot] >= 0:
            want = float(((xs[v] - xs[ids[v, slot]]) ** 2).sum())
            np.testing.assert_allclose(dists[v, slot], want, rtol=1e-4)


# ---------------------------------------------------------------------------
# quality: parity with the sequential reference + round behaviour
# ---------------------------------------------------------------------------

class TestQuality:
    def test_recall_beats_random_init(self, small_dataset):
        x, q, gt = small_dataset
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16)
        p0 = pools.init_random(jax.random.PRNGKey(7), x, 8, 16)
        built = grnnd.build_graph(jax.random.PRNGKey(7), x, cfg)
        r0 = recall.recall_at_k(search(x, p0.ids, q, k=10, ef=32).ids, gt)
        r1 = recall.recall_at_k(search(x, built.ids, q, k=10, ef=32).ids, gt)
        assert r1 > r0 + 0.2, (r0, r1)
        assert r1 > 0.9

    def test_parity_with_sequential_reference(self, small_dataset):
        """GRNND (parallel, disordered) must match sequential RNN-Descent.

        Per the paper's Fig-5 protocol, each method uses its own tuned
        construction parameters: sequential immediate writes propagate
        within a round, so the parallel snapshot-based rounds need more
        iterations to reach the same quality (this is exactly the T1/T2
        trade the paper studies in Fig 9).
        """
        x, q, gt = small_dataset
        xs = np.asarray(x)
        adj = rnnd_ref.build_graph_ref(xs, s=8, r=16, t1=2, t2=2, seed=0)
        ref_ids = jnp.asarray(rnnd_ref.adjacency_to_pool_arrays(adj, 16))
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=4, pairs_per_vertex=32)
        ours = grnnd.build_graph(jax.random.PRNGKey(8), x, cfg)
        r_ref = recall.recall_at_k(search(x, ref_ids, q, k=10, ef=32).ids, gt)
        r_ours = recall.recall_at_k(search(x, ours.ids, q, k=10, ef=32).ids, gt)
        # parallel adaptation must be within a few points of the CPU oracle
        assert r_ours >= r_ref - 0.05, (r_ref, r_ours)

    def test_reverse_edges_increase_degree(self):
        x = synthetic.make_preset(jax.random.PRNGKey(9), "tiny", 512)
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=1, t2=2, rho=0.6,
                                pairs_per_vertex=8)
        p = pools.init_random(jax.random.PRNGKey(10), x, 8, 16)
        p = grnnd.update_round(x, p, jax.random.PRNGKey(11), cfg)
        deg_before = float(jnp.mean(p.degree()))
        p2 = grnnd.reverse_edge_round(p, cfg)
        deg_after = float(jnp.mean(p2.degree()))
        assert deg_after >= deg_before

    def test_build_deterministic(self):
        x = synthetic.make_preset(jax.random.PRNGKey(12), "tiny", 256)
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=2, pairs_per_vertex=8)
        p1 = grnnd.build_graph(jax.random.PRNGKey(13), x, cfg)
        p2 = grnnd.build_graph(jax.random.PRNGKey(13), x, cfg)
        np.testing.assert_array_equal(p1.ids, p2.ids)

    def test_chunked_build_matches_unchunked(self):
        x = synthetic.make_preset(jax.random.PRNGKey(14), "tiny", 512)
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=2, pairs_per_vertex=8)
        cfg_c = cfg._replace(chunk_size=128)
        p1 = grnnd.build_graph(jax.random.PRNGKey(15), x, cfg)
        p2 = grnnd.build_graph(jax.random.PRNGKey(15), x, cfg_c)
        # chunking changes key->pair mapping, so graphs differ, but quality
        # must match; degrees should be close
        assert abs(float(jnp.mean(p1.degree())) -
                   float(jnp.mean(p2.degree()))) < 2.0


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

class TestSearch:
    def test_search_exact_on_full_graph(self):
        """On a complete-ish graph, beam search == brute force."""
        x = synthetic.make_preset(jax.random.PRNGKey(16), "tiny", 64)
        d = recall.brute_force_knn(x, x, 33)  # 32 neighbors + self
        graph = d[:, 1:]
        q = synthetic.queries_from(jax.random.PRNGKey(17), x, 32)
        gt = recall.brute_force_knn(x, q, 5)
        res = search(x, graph, q, k=5, ef=32)
        assert recall.recall_at_k(res.ids, gt) > 0.99

    def test_search_results_sorted_and_valid(self, small_dataset):
        x, q, gt = small_dataset
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=2, pairs_per_vertex=16)
        pool = grnnd.build_graph(jax.random.PRNGKey(18), x, cfg)
        res = search(x, pool.ids, q, k=10, ef=32)
        d = np.asarray(res.dists)
        assert np.all(np.diff(np.where(np.isinf(d), 1e30, d), axis=1) >= -1e-6)
        assert np.all(np.asarray(res.ids) < x.shape[0])

    def test_medoid_is_central(self):
        x = jnp.concatenate([
            jnp.zeros((5, 4)) + jnp.arange(5)[:, None] * 0.01,
            jnp.ones((1, 4)) * 100.0,
        ])
        assert int(medoid(x)) < 5

    def test_higher_ef_higher_recall(self, small_dataset):
        x, q, gt = small_dataset
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
        pool = grnnd.build_graph(jax.random.PRNGKey(19), x, cfg)
        r_lo = recall.recall_at_k(search(x, pool.ids, q, k=10, ef=16).ids, gt)
        r_hi = recall.recall_at_k(search(x, pool.ids, q, k=10, ef=96).ids, gt)
        assert r_hi >= r_lo
