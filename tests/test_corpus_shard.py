"""Corpus-sharded index suite: the ISSUE 7 shard-count-invariance tier.

The corpus-sharded layout (core/corpus_shard.py, DESIGN.md §11) slices
every O(N) operand — vectors, graph rows, validity, rescore tier, label
words, id map — into S contiguous owner partitions and runs the SAME
beam loop as `core.search.search` with per-step owner-combines.  The
combines are order-free (min/max/or with identity fill; exactly one
owner contributes per slot), so the whole safety argument is a bitwise
one, and this suite locks it:

  * **shard-count invariance** — `sharded_search` returns bitwise-
    identical ids, dists AND n_expanded to the replicated search for
    S ∈ {1, 2, 3, 4} (including the uneven last-shard padding), on all
    three precision rungs (fp32/bf16/int8 + fp32 rescore), filtered and
    unfiltered, dense and hashed (small-cap, real-collision) visited
    sets, tombstoned, and composed with the PR 6 optimized layout;
  * **id-map laws** — global→(shard, local)→global is the identity for
    any (N, S) including padded last shards (hypothesis property), and
    cross-shard `topr_merge` of per-shard top-k equals top-k of the
    concatenation for ANY partition of the candidates (the reduction
    the per-shard result merge relies on; hypothesis property);
  * **sharded-build quality** — the divide-and-conquer build
    (per-partition GRNND + cross-boundary merge-refine) clears the
    tests/test_recall.py floor through the sharded search itself;
  * **mutation routing** — a corpus-sharded `DynamicIndex.corpus_search`
    is bitwise `search()` in label space through insert/delete/compact
    churn, and the mesh-routed insert staging is exactly the in-process
    staging;
  * **cache-key regression** — the shard_map executable cache
    (`distributed._corpus_search_fn`) keys on every operand-presence
    flag: an unfiltered compile is never reused for a filtered call of
    identical shapes.

Fast tier runs in BOTH CI legs (REPRO_KERNEL_BACKEND=ref and
=interpret); the multi-device shard_map matrix and the quality tier are
subprocess/scale-bound and ride the nightly `slow` tier.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus_shard as CS
from repro.core import grnnd, labels as L, layout as LY, recall
from repro.core import vecstore as VS
from repro.core.search import search
from repro.data import synthetic
from repro.kernels import ops
from conftest import optional_hypothesis

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity


given, settings, st = optional_hypothesis()

K = 10
EF = 32
N = 260
NQ = 12
CFG = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)


@pytest.fixture(scope="module")
def case():
    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", N)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, NQ)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x, CFG)
    return x, q, pool


def _assert_same(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids),
                                  err_msg=f"{msg}/ids")
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists),
                                  err_msg=f"{msg}/dists")
    np.testing.assert_array_equal(np.asarray(a.n_expanded),
                                  np.asarray(b.n_expanded),
                                  err_msg=f"{msg}/n_expanded")


# ---------------------------------------------------------------------------
# id-map laws
# ---------------------------------------------------------------------------

def _assert_id_map_laws(n: int, s: int) -> None:
    """shard_of/local_of/global_of round-trip the full corpus and stay in
    range, including when the last shard is padded (n % s != 0)."""
    row0s, n_loc = CS.shard_bounds(n, s)
    assert len(row0s) == s and row0s[0] == 0
    assert n_loc == -(-n // s)          # ceil(n / s): minimal equal slices
    assert row0s == tuple(i * n_loc for i in range(s))
    g = np.arange(n, dtype=np.int64)
    sh, loc = CS.shard_of(g, n_loc), CS.local_of(g, n_loc)
    assert sh.min(initial=0) >= 0 and sh.max(initial=0) < s
    assert loc.min(initial=0) >= 0 and loc.max(initial=0) < n_loc
    np.testing.assert_array_equal(CS.global_of(sh, loc, n_loc), g)
    # ownership is contiguous: shard s owns exactly [row0, row0 + n_own)
    for i, row0 in enumerate(row0s):
        n_own = min(n_loc, n - row0)
        np.testing.assert_array_equal(sh == i,
                                      (g >= row0) & (g < row0 + n_own))


@pytest.mark.parametrize("n,s", [(1, 1), (7, 2), (260, 4), (100, 3),
                                 (64, 64), (5, 8)])
def test_id_map_round_trip(n, s):
    _assert_id_map_laws(n, s)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 16))
def test_id_map_round_trip_property(n, s):
    """For ANY corpus size and shard count — padding or not, more shards
    than rows or not — the global→(shard, local)→global map is the
    identity and ownership stays contiguous."""
    _assert_id_map_laws(n, s)


def _assert_merge_partition_law(ids: np.ndarray, dists: np.ndarray,
                                bounds: list, r: int) -> None:
    """topr_merge over a concatenation == topr_merge over per-group
    topr_merge outputs, for the given partition boundaries (the reduction
    the cross-shard result merge performs; groups here mirror disjoint
    shard ownership, padded with the (-1, +inf) identity fill)."""
    ids_j = jnp.asarray(ids[None], jnp.int32)
    d_j = jnp.asarray(dists[None], jnp.float32)
    want = ops.topr_merge(ids_j, d_j, r)
    parts_i, parts_d = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if lo == hi:
            continue  # an empty cell contributes the (-1, +inf) identity
        gi, gd = ops.topr_merge(ids_j[:, lo:hi], d_j[:, lo:hi], r)
        parts_i.append(gi)
        parts_d.append(gd)
    if not parts_i:
        parts_i = [jnp.full((1, r), -1, jnp.int32)]
        parts_d = [jnp.full((1, r), jnp.inf, jnp.float32)]
    got = ops.topr_merge(jnp.concatenate(parts_i, axis=1),
                         jnp.concatenate(parts_d, axis=1), r)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(want[1]), np.asarray(got[1]))


def test_cross_shard_merge_partition_fixed():
    ids = np.array([5, 0, 3, -1, 7, 2, 9], np.int32)
    dists = np.array([3., 1., 4., np.inf, 0.5, 2., 6.], np.float32)
    for bounds in ([0, 3, 7], [0, 1, 4, 7], [0, 7], [0, 0, 7]):
        _assert_merge_partition_law(ids, dists, bounds, r=4)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_cross_shard_merge_partition_property(data):
    """Merging per-shard top-r results is exact for ANY partition: the
    two-level reduction equals the single-level top-r of the full
    candidate set.  Distinct ids carry distinct distances (shard
    ownership is disjoint, and dedup-by-min makes the rest order-free),
    with empty slots at the (-1, +inf) identity."""
    w = data.draw(st.integers(1, 24))
    r = data.draw(st.integers(1, 12))
    seed = data.draw(st.integers(0, 2**16))
    n_cuts = data.draw(st.integers(0, min(4, w)))
    rng = np.random.default_rng(seed)
    ids = rng.permutation(2 * w)[:w].astype(np.int32)   # distinct ids
    dists = rng.permutation(4 * w)[:w].astype(np.float32)  # distinct dists
    empty = rng.random(w) < 0.25
    ids[empty] = -1
    dists[empty] = np.inf
    cuts = sorted(rng.choice(w + 1, size=n_cuts, replace=True).tolist())
    _assert_merge_partition_law(ids, dists, [0] + cuts + [w], r)


# ---------------------------------------------------------------------------
# shard-count invariance: sharded == replicated, bitwise (reference executor)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
@pytest.mark.parametrize("precision", VS.PRECISIONS)
def test_sharded_search_bitwise_equal(case, precision, n_shards):
    """The acceptance core: slicing the corpus changes NOTHING the caller
    can observe — ids, dists, and the n_expanded trajectory are bitwise
    identical for any shard count (S=3 leaves the last shard padded), on
    every precision rung, the quantized rungs rescoring through the
    owner-sliced fp32 tier."""
    x, q, pool = case
    vs = x if precision == "fp32" else VS.encode(x, precision)
    rescore = None if precision == "fp32" else x
    base = search(vs, pool.ids, q, k=K, ef=EF, rescore=rescore)
    idx = CS.shard(vs, pool.ids, n_shards, rescore=rescore)
    assert idx.n_shards == n_shards and idx.n == N
    _assert_same(base, idx.search(q, k=K, ef=EF),
                 f"{precision}/S{n_shards}")


@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_search_filtered_bitwise_equal(case, n_shards):
    """Filtered search: vertex label words shard with their owners, the
    per-query predicate stays replicated — the route-through result set
    is bitwise unchanged and every returned id obeys its predicate."""
    x, q, pool = case
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(3), (N,), 0, 20), 20)
    fw = L.random_query_filters(jax.random.PRNGKey(4), NQ, 20, 0.25)
    base = search(x, pool.ids, q, k=K, ef=EF, labels=store, filter=fw)
    idx = CS.shard(x, pool.ids, n_shards, labels=store)
    got = idx.search(q, k=K, ef=EF, filter=fw)
    _assert_same(base, got, f"filtered/S{n_shards}")
    assert L.predicate_fraction(got.ids, fw, store.words) == 1.0


@pytest.mark.parametrize("n_shards", [2, 3])
def test_sharded_search_hashed_visited_bitwise_equal(case, n_shards):
    """The hashed visited set is replicated on GLOBAL ids outside the
    kernel (the kernel probes a dummy table), so even a small-cap table
    with real collisions — where which-id-wins depends on insertion
    order — stays bitwise shard-count-invariant."""
    x, q, pool = case
    base = search(x, pool.ids, q, k=K, ef=EF, visited="hashed",
                  visited_cap=64)
    idx = CS.shard(x, pool.ids, n_shards)
    _assert_same(base, idx.search(q, k=K, ef=EF, visited="hashed",
                                  visited_cap=64), f"hashed/S{n_shards}")


def test_sharded_search_tombstones_bitwise_equal(case):
    """The validity mask shards with its owners; the entry's own flag is
    captured at shard() time."""
    x, q, pool = case
    valid = jax.random.bernoulli(jax.random.PRNGKey(5), 0.85, (N,))
    base = search(x, pool.ids, q, k=K, ef=EF, valid=valid)
    idx = CS.shard(x, pool.ids, 2, valid=valid)
    _assert_same(base, idx.search(q, k=K, ef=EF), "tombstones")


def test_shard_optimized_composition_bitwise_equal(case):
    """The PR 6 composition contract: sharding an OptimizedIndex slices
    the PERMUTED rows and the inverse map, so the corpus-sharded search
    over the optimized layout still answers in the caller's original
    numbering — bitwise equal to both the optimized and the raw search,
    with the full stack (int8 + rescore + filter) on top."""
    x, q, pool = case
    vs = VS.encode(x, "int8")
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(6), (N,), 0, 12), 12)
    fw = L.random_query_filters(jax.random.PRNGKey(7), NQ, 12, 0.3)
    opt = LY.optimize(vs, pool, order="hub", rescore=x, labels=store)
    want = opt.search(q, k=K, ef=EF, filter=fw)
    for s in (2, 4):
        idx = CS.shard_optimized(opt, s)
        _assert_same(want, idx.search(q, k=K, ef=EF, filter=fw),
                     f"opt/S{s}")
    _assert_same(search(vs, pool.ids, q, k=K, ef=EF, rescore=x,
                        labels=store, filter=fw), want, "opt-vs-raw")


def test_memory_report_scales_down(case):
    """The N-ceiling claim at unit scale: per-shard O(N) bytes shrink as
    ~1/S while the replicated baseline stays put."""
    x, _, pool = case
    per, repl = [], []
    for s in (1, 2, 4):
        m = CS.memory_report(CS.shard(x, pool.ids, s, rescore=None))
        per.append(m["per_shard_bytes"])
        repl.append(m["replicated_bytes"])
    assert repl[0] == repl[1] == repl[2]
    assert per[0] == repl[0]            # S=1 holds everything
    assert per[0] > per[1] > per[2]     # and the slices shrink with S
    assert per[1] <= repl[1] // 2 + 1024  # ~1/S plus replicated entry row


def test_mesh_executor_single_device_and_cache_key(case):
    """In-process 1-device mesh: the shard_map executor is bitwise the
    reference executor, and the executable cache keys on the filter
    operands — an unfiltered compile of identical shapes is never reused
    for a filtered call."""
    from repro.core.distributed import _corpus_search_fn
    x, q, pool = case
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(8), (N,), 0, 16), 16)
    fw = L.random_query_filters(jax.random.PRNGKey(9), NQ, 16, 0.3)
    mesh = jax.make_mesh((1,), ("corp",))
    idx = CS.shard(x, pool.ids, 1, labels=store)
    got_u = idx.search(q, k=K, ef=EF, mesh=mesh, axes=("corp",))
    before = _corpus_search_fn.cache_info().currsize
    got_f = idx.search(q, k=K, ef=EF, filter=fw, mesh=mesh, axes=("corp",))
    after = _corpus_search_fn.cache_info().currsize
    assert after == before + 1  # has_filter keys the executable
    _assert_same(search(x, pool.ids, q, k=K, ef=EF), got_u, "mesh-u")
    _assert_same(search(x, pool.ids, q, k=K, ef=EF, labels=store,
                        filter=fw), got_f, "mesh-f")


def test_sharded_build_single_shard_is_plain_build(case):
    """S=1 short-circuits to build_graph: same key, same pool, bitwise."""
    x, _, pool = case
    p1 = CS.sharded_build(jax.random.PRNGKey(2), x, CFG, 1)
    np.testing.assert_array_equal(np.asarray(pool.ids), np.asarray(p1.ids))


def test_sharded_build_pool_invariants(case):
    """Structural contract of the divide-and-conquer build (the recall
    floor is the slow quality tier): the merged pool is a standard global
    (N, R) pool — ids in range, no self-edges, ascending per-row dists —
    that contains cross-boundary edges (the whole point of the
    merge-refine rounds) and searches correctly end to end."""
    x, q, _ = case
    pool = CS.sharded_build(jax.random.PRNGKey(3), x, CFG, 2,
                            merge_rounds=1)
    ids = np.asarray(pool.ids)
    dists = np.asarray(pool.dists)
    assert ids.shape == (N, CFG.r)
    assert ids.max() < N and ids.min() >= -1
    row0 = CS.shard_bounds(N, 2)[1]
    crossing = 0
    for v in range(N):
        row = ids[v][ids[v] >= 0]
        assert v not in row, v
        assert len(set(row.tolist())) == len(row), v
        dv = dists[v][ids[v] >= 0]
        assert np.all(np.diff(dv) >= 0), v
        crossing += int(np.any((row >= row0) != (v >= row0)))
    assert crossing > N // 4, crossing  # boundaries actually stitched
    res = CS.shard(x, pool.ids, 2).search(q, k=K, ef=EF)
    assert np.asarray(res.ids)[:, 0].min() >= 0


# ---------------------------------------------------------------------------
# quality + scale: nightly tier
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_build_reaches_recall_floor():
    """The divide-and-conquer build (independent per-partition GRNND +
    cross-boundary merge-refine) must clear the tests/test_recall.py
    floor within the default bounded merge rounds — searched through the
    corpus-sharded path itself, so the whole stack is on the hook."""
    if ops.effective_backend() == "interpret":
        pytest.skip("quality tier needs the n=1200 corpus; interpret "
                    "kernels step the grid from Python")
    cfg = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16,
                            order="disordered")
    x = synthetic.make_preset(jax.random.PRNGKey(0), "sift-like", 1200)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 128)
    gt = recall.brute_force_knn(x, q, K)
    for s in (2, 4):
        pool = CS.sharded_build(jax.random.PRNGKey(2), x, cfg, s)
        idx = CS.shard(x, pool.ids, s)
        rec = recall.recall_at_k(idx.search(q, k=K, ef=48).ids, gt)
        assert rec >= 0.86, (s, rec)


@pytest.mark.slow
def test_dynamic_corpus_search_label_stability():
    """Insert/delete/compact churn on a DynamicIndex, then corpus_search
    at S ∈ {1, 2, 4}: bitwise `search()` in label space — external-label
    stability composes with the global→(shard, local) map."""
    from repro.core.dynamic import DynamicConfig, DynamicIndex
    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 300)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 16)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x[:240], CFG)
    idx = DynamicIndex(x[:240], pool,
                       DynamicConfig(refine_rounds=1, compact_threshold=0.2))
    idx.insert(x[240:])
    idx.delete(np.arange(0, 240, 5))    # 48 tombstones -> triggers compact
    base = idx.search(q, k=K, ef=EF)
    for s in (1, 2, 4):
        _assert_same(base, idx.corpus_search(q, s, k=K, ef=EF),
                     f"dyn/S{s}")
    # deleted labels stay gone through the sharded path too
    got = np.asarray(idx.corpus_search(q, 2, k=K, ef=EF).ids)
    assert not (set(got[got >= 0].tolist())
                & set(range(0, 240, 5))), "deleted label returned"


_SLOW_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import corpus_shard as CS
    from repro.core import grnnd, labels as L, layout as LY
    from repro.core import vecstore as VS
    from repro.core.distributed import _corpus_search_fn
    from repro.core.search import search
    from repro.data import synthetic

    N, NQ, K, EF = 300, 18, 10, 32
    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", N)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, NQ)
    cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x, cfg)
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(3), (N,), 0, 20), 20)
    fw = L.random_query_filters(jax.random.PRNGKey(4), NQ, 20, 0.25)

    def same(a, b):
        return (np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
                and np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
                and np.array_equal(np.asarray(a.n_expanded),
                                   np.asarray(b.n_expanded)))

    out = {}
    for s in (2, 4):
        mesh = jax.make_mesh((s,), ("data",), devices=jax.devices()[:s])
        idx = CS.shard(x, pool.ids, s)
        out[f"fp32-S{s}"] = same(
            search(x, pool.ids, q, k=K, ef=EF),
            idx.search(q, k=K, ef=EF, mesh=mesh))
        out[f"hashed-S{s}"] = same(
            search(x, pool.ids, q, k=K, ef=EF, visited="hashed",
                   visited_cap=64),
            idx.search(q, k=K, ef=EF, visited="hashed", visited_cap=64,
                       mesh=mesh))
        vs = VS.encode(x, "int8")
        idx8 = CS.shard(vs, pool.ids, s, rescore=x, labels=store)
        out[f"int8-S{s}"] = same(
            search(vs, pool.ids, q, k=K, ef=EF, rescore=x),
            idx8.search(q, k=K, ef=EF, mesh=mesh))
        out[f"filtered-S{s}"] = same(
            search(vs, pool.ids, q, k=K, ef=EF, rescore=x, labels=store,
                   filter=fw),
            idx8.search(q, k=K, ef=EF, filter=fw, mesh=mesh))
        opt = LY.optimize(x, pool, order="bfs")
        out[f"layout-S{s}"] = same(
            opt.search(q, k=K, ef=EF),
            CS.shard_optimized(opt, s).search(q, k=K, ef=EF, mesh=mesh))

    # cache-key regression on the multi-device executor
    mesh2 = jax.make_mesh((2,), ("ck",), devices=jax.devices()[:2])
    idxf = CS.shard(x, pool.ids, 2, labels=store)
    _ = idxf.search(q, k=K, ef=EF, mesh=mesh2, axes=("ck",))
    before = _corpus_search_fn.cache_info().currsize
    got = idxf.search(q, k=K, ef=EF, filter=fw, mesh=mesh2, axes=("ck",))
    after = _corpus_search_fn.cache_info().currsize
    out["cache_key"] = {
        "grew": after == before + 1,
        "pred_ok": float(L.predicate_fraction(got.ids, fw, store.words)),
        "matches": same(search(x, pool.ids, q, k=K, ef=EF, labels=store,
                               filter=fw), got),
    }

    # mesh-routed insert staging == in-process staging, then a sharded
    # mesh search over the churned index
    from repro.core.dynamic import DynamicConfig, DynamicIndex
    dc = DynamicConfig(refine_rounds=1)
    plain = DynamicIndex(x[:260], pool_b := grnnd.build_graph(
        jax.random.PRNGKey(5), x[:260], cfg), dc)
    mesh3 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    routed = DynamicIndex(x[:260], pool_b, dc, mesh=mesh3)
    lp = plain.insert(x[260:])
    lr = routed.insert(x[260:])
    out["dyn_insert"] = {
        "labels": np.array_equal(lp, lr),
        "pool_ids": np.array_equal(np.asarray(plain.pool.ids),
                                   np.asarray(routed.pool.ids)),
        "pool_dists": np.array_equal(np.asarray(plain.pool.dists),
                                     np.asarray(routed.pool.dists)),
    }
    m2 = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    out["dyn_mesh_search"] = same(
        routed.search(q, k=K, ef=EF),
        routed.corpus_search(q, 2, k=K, ef=EF, mesh=m2))
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def mesh_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SLOW_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
@pytest.mark.parametrize("shards", [2, 4])
@pytest.mark.parametrize("mode", ["fp32", "hashed", "int8", "filtered",
                                  "layout"])
def test_mesh_shard_count_invariance(mesh_results, shards, mode):
    """2/4-shard shard_map over forced host devices — each device holding
    only its slice — stays bitwise-identical to the replicated search:
    plain fp32, small-cap hashed visited, int8 + fp32 rescore, the
    filtered full stack, and the optimized-layout composition."""
    assert mesh_results[f"{mode}-S{shards}"]


@pytest.mark.slow
def test_mesh_filter_operands_key_executable_cache(mesh_results):
    res = mesh_results["cache_key"]
    assert res["grew"]
    assert res["pred_ok"] == 1.0
    assert res["matches"]


@pytest.mark.slow
def test_mesh_routed_insert_matches_in_process(mesh_results):
    """Owner-shard mutation routing (DESIGN.md §11.3): the mesh-routed
    symmetric-edge staging produces the identical pool — same labels,
    same ids, same dists — as the in-process staging, and a corpus-
    sharded mesh search over the churned index matches its own search."""
    res = mesh_results["dyn_insert"]
    assert res["labels"] and res["pool_ids"] and res["pool_dists"]
    assert mesh_results["dyn_mesh_search"]
