"""Distributed (shard_map) GRNND build: multi-device correctness.

Runs on 8 forced host devices in a subprocess (device count must be set
before jax initializes, so these tests shell out — the same pattern the
dry-run uses).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Subprocess multi-device build (~14 s) — nightly tier.
pytestmark = pytest.mark.slow

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.core import grnnd, recall, distributed
    from repro.core.search import search
    from repro.data import synthetic

    key = jax.random.PRNGKey(0)
    x = synthetic.make_preset(key, "tiny", 2048)
    cfg = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16)
    q = synthetic.queries_from(jax.random.PRNGKey(2), x, 200)
    gt = recall.brute_force_knn(x, q, 10)

    out = {}
    mesh = jax.make_mesh((8,), ("data",))
    for comm in ("allgather", "a2a"):
        pool = distributed.sharded_build_graph(
            mesh, ("data",), jax.random.PRNGKey(1), x, cfg, comm=comm)
        ids = jax.device_get(pool.ids)
        res = search(x, jnp.asarray(ids), q, k=10, ef=32)
        out[comm] = recall.recall_at_k(res.ids, gt)

    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    pool = distributed.sharded_build_graph(
        mesh2, ("pod", "data"), jax.random.PRNGKey(1), x, cfg)
    res = search(x, jnp.asarray(jax.device_get(pool.ids)), q, k=10, ef=32)
    out["two_axis"] = recall.recall_at_k(res.ids, gt)

    # single-device baseline with identical cfg/key for quality comparison
    pool1 = grnnd.build_graph(jax.random.PRNGKey(1), x, cfg)
    res1 = search(x, pool1.ids, q, k=10, ef=32)
    out["single"] = recall.recall_at_k(res1.ids, gt)
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_allgather_build_quality(dist_results):
    assert dist_results["allgather"] > 0.9


def test_a2a_matches_allgather(dist_results):
    assert abs(dist_results["a2a"] - dist_results["allgather"]) < 0.02


def test_multi_axis_mesh_build(dist_results):
    assert dist_results["two_axis"] > 0.9


def test_sharded_parity_with_single_device(dist_results):
    assert dist_results["allgather"] >= dist_results["single"] - 0.05
