"""Query-sharded distributed_search: bitwise parity with single-device.

Locks the DESIGN.md §6.4 contract — searches are embarrassingly parallel
over queries, so any shard count must return bitwise-identical results —
for both visited representations, including the query-padding path
(Q not divisible by the shard count).  Same forced-host-device subprocess
pattern as tests/test_distributed_build.py.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Subprocess with 8 forced host devices (~15 s) — nightly tier.
pytestmark = pytest.mark.slow

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import grnnd, distributed
    from repro.core.search import search
    from repro.data import synthetic

    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 600)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 100)  # 100 % 8 != 0
    cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x, cfg)
    mesh = jax.make_mesh((8,), ("data",))

    out = {}
    for vis in ("dense", "hashed"):
        ref = search(x, pool.ids, q, k=10, ef=32, visited=vis)
        got = distributed.distributed_search(
            mesh, ("data",), x, pool.ids, q, k=10, ef=32, visited=vis)
        out[vis] = {
            "ids": np.array_equal(np.asarray(ref.ids), np.asarray(got.ids)),
            "dists": np.array_equal(np.asarray(ref.dists),
                                    np.asarray(got.dists)),
            "n_expanded": np.array_equal(np.asarray(ref.n_expanded),
                                         np.asarray(got.n_expanded)),
            "shape_ok": got.ids.shape == ref.ids.shape,
        }
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_search_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("visited", ["dense", "hashed"])
def test_sharded_search_bitwise_parity(dist_search_results, visited):
    res = dist_search_results[visited]
    assert res["shape_ok"]       # pad rows sliced back off
    assert res["ids"]
    assert res["dists"]
    assert res["n_expanded"]
