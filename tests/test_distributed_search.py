"""Query-sharded distributed_search: bitwise parity with single-device.

Locks the DESIGN.md §6.4 contract — searches are embarrassingly parallel
over queries, so any shard count must return bitwise-identical results —
for both visited representations, including the query-padding path
(Q not divisible by the shard count).  Same forced-host-device subprocess
pattern as tests/test_distributed_build.py.

ISSUE 5 grows the suite with the filtered path (DESIGN.md §9):

  * shard-count invariance across 1/2/4 shards, for the unfiltered AND
    the filtered search — the per-query predicate words shard with the
    queries, so the route-through beam and result heap stay shard-local;
  * a cache-key regression: the shard_map executable cache keys on the
    presence of the filter operands (`has_filter`), so an unfiltered call
    followed by a filtered call of identical shapes can never reuse a
    stale unfiltered executable (every filtered id must satisfy its
    predicate, and the cache must grow between the calls).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Subprocess with 8 forced host devices (~15 s) — nightly tier.
pytestmark = pytest.mark.slow

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import grnnd, distributed
    from repro.core import labels as L
    from repro.core.distributed import _sharded_search_fn
    from repro.core.search import search
    from repro.data import synthetic

    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 600)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 100)  # 100 % 8 != 0
    cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x, cfg)
    mesh = jax.make_mesh((8,), ("data",))
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(3), (600,), 0, 30), 30)
    fw = L.random_query_filters(jax.random.PRNGKey(4), 100, 30, 0.2)

    def same(a, b):
        return {
            "ids": np.array_equal(np.asarray(a.ids), np.asarray(b.ids)),
            "dists": np.array_equal(np.asarray(a.dists),
                                    np.asarray(b.dists)),
            "n_expanded": np.array_equal(np.asarray(a.n_expanded),
                                         np.asarray(b.n_expanded)),
            "shape_ok": b.ids.shape == a.ids.shape,
        }

    out = {}
    for vis in ("dense", "hashed"):
        ref = search(x, pool.ids, q, k=10, ef=32, visited=vis)
        got = distributed.distributed_search(
            mesh, ("data",), x, pool.ids, q, k=10, ef=32, visited=vis)
        out[vis] = same(ref, got)

    # shard-count invariance, unfiltered + filtered, on device subsets
    ref_u = search(x, pool.ids, q, k=10, ef=32)
    ref_f = search(x, pool.ids, q, k=10, ef=32, labels=store, filter=fw)
    for s in (1, 2, 4):
        m = jax.make_mesh((s,), ("data",), devices=jax.devices()[:s])
        got_u = distributed.distributed_search(
            m, ("data",), x, pool.ids, q, k=10, ef=32)
        got_f = distributed.distributed_search(
            m, ("data",), x, pool.ids, q, k=10, ef=32,
            labels=store, filter=fw)
        out[f"shards{s}-unfiltered"] = same(ref_u, got_u)
        out[f"shards{s}-filtered"] = same(ref_f, got_f)

    # cache-key regression: unfiltered then filtered at IDENTICAL shapes
    # on a fresh mesh axis name -> the cache must add an entry (has_filter
    # is part of the key) and the filtered results must obey the predicate
    m2 = jax.make_mesh((2,), ("ck",), devices=jax.devices()[:2])
    _ = distributed.distributed_search(m2, ("ck",), x, pool.ids, q,
                                       k=10, ef=32)
    before = _sharded_search_fn.cache_info().currsize
    got = distributed.distributed_search(m2, ("ck",), x, pool.ids, q,
                                         k=10, ef=32,
                                         labels=store, filter=fw)
    after = _sharded_search_fn.cache_info().currsize
    out["cache_key"] = {
        "grew": after == before + 1,
        "pred_ok": float(L.predicate_fraction(got.ids, fw, store.words)),
        "matches_single_device": np.array_equal(np.asarray(ref_f.ids),
                                                np.asarray(got.ids)),
    }
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_search_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("visited", ["dense", "hashed"])
def test_sharded_search_bitwise_parity(dist_search_results, visited):
    res = dist_search_results[visited]
    assert res["shape_ok"]       # pad rows sliced back off
    assert res["ids"]
    assert res["dists"]
    assert res["n_expanded"]


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("mode", ["unfiltered", "filtered"])
def test_shard_count_invariance(dist_search_results, shards, mode):
    """1/2/4 shards return bitwise-identical results to the single-device
    search, with and without a per-query filter predicate."""
    res = dist_search_results[f"shards{shards}-{mode}"]
    assert res["shape_ok"]
    assert res["ids"]
    assert res["dists"]
    assert res["n_expanded"]


def test_filter_operands_in_shard_map_cache_key(dist_search_results):
    """An unfiltered compile must never be reused for a filtered batch of
    identical shapes: the cache grows, the filtered results match the
    single-device filtered search, and every id passes its predicate."""
    res = dist_search_results["cache_key"]
    assert res["grew"]
    assert res["pred_ok"] == 1.0
    assert res["matches_single_device"]
