"""Dry-run machinery validation on a small forced-device mesh.

Exercises the exact code path of launch/dryrun.py (spec building, sharding
attachment, lower+compile, cost probes, collective parsing) with
REPRO_MESH_OVERRIDE=4,4 on 16 forced host devices — fast enough for CI.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Subprocess lower+compile probes (~12 s) — nightly tier.
pytestmark = pytest.mark.slow

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    os.environ["REPRO_MESH_OVERRIDE"] = "4,4"
    import json
    from repro.launch.dryrun import run_cell, collective_bytes

    res = run_cell("mamba2-130m", "train_4k", "single")
    out = {
        "status": res["status"],
        "flops": res["cost"]["flops"],
        "raw_flops": res["cost_raw_scanned"]["flops"],
        "coll": res["collectives"]["total_bytes"],
        "temp": res["memory"]["temp_size_bytes"],
    }
    res2 = run_cell("gemma2-2b", "long_500k", "single", cost_probes=False)
    out["gemma_long_status"] = res2["status"]
    res3 = run_cell("musicgen-large", "long_500k", "single")
    out["musicgen_long"] = res3["status"]

    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dryrun_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_cell_compiles(dryrun_results):
    assert dryrun_results["status"] == "ok"


def test_cost_probe_corrects_scan_undercount(dryrun_results):
    """Extrapolated FLOPs must be ~n_layers x the body-once raw count."""
    r = dryrun_results
    assert r["flops"] > 5 * r["raw_flops"], (r["flops"], r["raw_flops"])


def test_collectives_parsed(dryrun_results):
    assert dryrun_results["coll"] > 0


def test_long_context_cells(dryrun_results):
    # gemma2 has local+global alternating -> eligible; musicgen skips
    assert dryrun_results["gemma_long_status"] == "ok"
    assert dryrun_results["musicgen_long"] == "skipped"


def test_collective_parser_units():
    from repro.launch.dryrun import collective_bytes
    hlo = """
      %ag = bf16[4,128]{1,0} all-gather(%x), replica_groups={}
      %ar = f32[16]{0} all-reduce(%y), to_apply=%sum
      %cp = f32[8,8]{1,0} collective-permute(%z)
      %nothing = f32[2]{0} add(%a, %b)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 4 * 128 * 2
    assert out["all-reduce"] == 16 * 4
    assert out["collective-permute"] == 64 * 4
    assert out["total_bytes"] == 4 * 128 * 2 + 64 + 256
    assert out["n_all-gather"] == 1
