"""Dynamic-index suite (PR CI fast tier): ISSUE 3 acceptance contracts.

Four contracts:

  * **incremental quality** — inserting 10% new vectors through
    `DynamicIndex` lands within 2 recall points of a from-scratch build on
    the same final corpus, at < 25% of the rebuild's propagation-round
    count (the acceptance bound; fig10 measures the same quantities);
  * **delete-mask parity** — the fused `search_expand` kernel (interpret
    mode) matches the ref.py oracle bitwise with a tombstone mask, per the
    same common-jit-context convention as tests/test_search_parity.py;
  * **deletion semantics** — tombstoned vertices vanish from results
    immediately and exactly (no routing through them either: the result
    equals a search over a physically rebuilt live graph's validity view);
  * **compaction** — `compact()` preserves search results exactly, in
    label space (parametrized sweep + hypothesis property test).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grnnd, recall
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.pools import insert_requests, Requests
from repro.core.search import _table_insert, medoid, search
from repro.data import synthetic
from repro.kernels import ref
from repro.kernels.search_expand import search_expand_pallas
from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

K = 10
EF = 48
# the fast-tier preset (tests/test_recall.py): 9 propagation rounds/build
CFG = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16)


@pytest.fixture(scope="module")
def corpus():
    x = synthetic.make_preset(jax.random.PRNGKey(0), "sift-like", 1200)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 128)
    gt = recall.brute_force_knn(x, q, K)
    return x, q, gt


@pytest.fixture(scope="module")
def churned(corpus):
    """90% base build + 10% online insert, plus the rebuild baseline."""
    x, _, _ = corpus
    n_base = int(x.shape[0] * 0.9)
    pool_base = grnnd.build_graph(jax.random.PRNGKey(2), x[:n_base], CFG)
    pool_full = grnnd.build_graph(jax.random.PRNGKey(2), x, CFG)
    idx = DynamicIndex(
        x[:n_base], pool_base,
        DynamicConfig(seed_k=8, seed_ef=EF, refine_rounds=2,
                      pairs_per_vertex=CFG.pairs_per_vertex))
    idx.insert(x[n_base:])
    return idx, pool_full


# ---------------------------------------------------------------------------
# acceptance: insert-then-search recall vs from-scratch rebuild
# ---------------------------------------------------------------------------

def test_insert_recall_within_two_points_of_rebuild(corpus, churned):
    x, q, gt = corpus
    idx, pool_full = churned
    rec_rebuild = recall.recall_at_k(
        search(x, pool_full.ids, q, k=K, ef=EF).ids, gt)
    # labels coincide with x-row indices here, so gt applies unchanged
    rec_dyn = recall.recall_at_k(idx.search(q, k=K, ef=EF).ids, gt)
    assert rec_dyn >= rec_rebuild - 0.02, (rec_dyn, rec_rebuild)


def test_insert_cost_under_quarter_of_rebuild_rounds(churned):
    idx, _ = churned
    rebuild_rounds = CFG.t1 * CFG.t2
    assert idx.rounds_run < 0.25 * rebuild_rounds, (
        idx.rounds_run, rebuild_rounds)


def test_insert_returns_monotone_labels_and_grows_capacity(corpus):
    x, _, _ = corpus
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x[:200], CFG)
    idx = DynamicIndex(x[:200], pool,
                       DynamicConfig(refine_rounds=1, min_capacity=64))
    assert idx.capacity == 256  # next pow2 >= 200
    labs = idx.insert(x[200:280])
    assert labs.tolist() == list(range(200, 280))
    assert idx.capacity == 512  # doubled, not re-sized per insert
    assert idx.n_live == 280 and len(idx) == 280
    # searching still returns live labels only
    res = idx.search(x[:4], k=5, ef=16)
    assert np.asarray(res.ids).max() < 280


# ---------------------------------------------------------------------------
# delete-mask parity: fused kernel vs oracle, bitwise
# ---------------------------------------------------------------------------

def _expand_case(seed, qn, r, n, d, h, live_frac):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4, k5 = jax.random.split(k, 5)
    x = synthetic.vector_dataset(k1, n, d, n_clusters=max(2, n // 16))
    q = synthetic.queries_from(k2, x, qn)
    nbrs = jax.random.randint(k3, (qn, r), -1, n)
    tab = _table_insert(
        jnp.full((qn, h), -1, jnp.int32),
        jnp.where(jax.random.bernoulli(k4, 0.5, (qn, r)), nbrs, -1))
    valid = jax.random.bernoulli(k5, live_frac, (n,))
    return x, q, nbrs, tab, valid


@pytest.mark.parametrize("qn,r,n,d,h,live_frac", [
    (8, 10, 64, 12, 32, 0.7),
    (5, 7, 50, 33, 16, 0.5),    # D not lane-aligned, odd shapes
    (4, 8, 40, 16, 1, 0.9),     # H = 1: the dense-path dummy table
    (3, 6, 30, 8, 3, 0.0),      # everything tombstoned
    (3, 6, 30, 8, 256, 1.0),    # nothing tombstoned == legacy path
])
def test_expand_delete_mask_matches_oracle(qn, r, n, d, h, live_frac):
    x, q, nbrs, tab, valid = _expand_case(17, qn, r, n, d, h, live_frac)
    got = search_expand_pallas(x, q, nbrs, tab, valid, interpret=True)
    want = jax.jit(ref.search_expand_ref)(x, q, nbrs, tab, valid)
    for name, g, w in zip(("ids", "dists", "fresh"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_expand_all_ones_mask_is_legacy_bitwise():
    x, q, nbrs, tab, _ = _expand_case(19, 6, 8, 48, 16, 32, 1.0)
    legacy = search_expand_pallas(x, q, nbrs, tab, None, interpret=True)
    masked = search_expand_pallas(x, q, nbrs, tab,
                                  jnp.ones((48,), bool), interpret=True)
    for g, w in zip(legacy, masked):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# deletion semantics + compaction exactness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_index(corpus):
    x, _, _ = corpus
    x = x[:600]
    pool = grnnd.build_graph(jax.random.PRNGKey(3), x, CFG)
    return x, pool


def _fresh_index(small_index):
    x, pool = small_index
    return DynamicIndex(x, pool, DynamicConfig(refine_rounds=1,
                                               compact_threshold=0.9))


def test_deleted_labels_never_returned(small_index, corpus):
    _, q, _ = corpus
    idx = _fresh_index(small_index)
    dels = np.arange(0, 600, 5)          # 20%
    assert idx.delete(dels) == dels.size
    assert idx.delete(dels) == 0         # idempotent no-op
    with pytest.raises(KeyError):
        idx.delete(np.array([10_000]))
    res = idx.search(q, k=K, ef=EF)
    got = set(np.asarray(res.ids).ravel().tolist()) - {-1}
    assert not (got & set(dels.tolist()))
    # quality against the LIVE ground truth stays high
    rec = recall.recall_at_k(res.ids, idx.exact_knn(q, K))
    assert rec >= 0.80, rec


@pytest.mark.parametrize("seed,frac", [(0, 0.1), (1, 0.33), (2, 0.6)])
def test_compact_preserves_search_exactly(small_index, corpus, seed, frac):
    _, q, _ = corpus
    idx = _fresh_index(small_index)
    rng = np.random.default_rng(seed)
    dels = rng.choice(600, size=int(600 * frac), replace=False)
    idx.delete(np.sort(dels))
    before = idx.search(q, k=K, ef=EF)
    gt_before = idx.exact_knn(q, K)
    idx.compact()
    assert idx.size == idx.n_live == 600 - dels.size
    after = idx.search(q, k=K, ef=EF)
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))
    np.testing.assert_array_equal(np.asarray(before.dists),
                                  np.asarray(after.dists))
    np.testing.assert_array_equal(np.asarray(gt_before),
                                  np.asarray(idx.exact_knn(q, K)))


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_compact_preserves_search_property(data):
    """Hypothesis sweep of (delete set, query set) — compaction may never
    change a result, for any mutation history the strategy generates."""
    x = synthetic.make_preset(jax.random.PRNGKey(4), "tiny", 220)
    pool = grnnd.build_graph(jax.random.PRNGKey(5),  x,
                             grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2,
                                               pairs_per_vertex=8))
    idx = DynamicIndex(x, pool, DynamicConfig(refine_rounds=1,
                                              compact_threshold=0.95))
    dels = data.draw(st.sets(st.integers(0, 219), min_size=1, max_size=80))
    qseed = data.draw(st.integers(0, 2**16))
    idx.delete(np.sort(np.fromiter(dels, np.int64)))
    q = synthetic.queries_from(jax.random.PRNGKey(qseed), x, 16)
    before = idx.search(q, k=5, ef=16)
    idx.compact()
    after = idx.search(q, k=5, ef=16)
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))
    np.testing.assert_array_equal(np.asarray(before.dists),
                                  np.asarray(after.dists))


def test_delete_retry_after_compact_is_noop(small_index):
    """At-least-once delivery: re-deleting a batch whose rows a compaction
    already reclaimed must return 0, not raise — only labels this index
    never issued are errors."""
    idx = _fresh_index(small_index)
    dels = np.arange(40)
    assert idx.delete(dels) == 40
    idx.compact()
    assert idx.delete(dels) == 0          # physically gone -> still a no-op
    with pytest.raises(KeyError):
        idx.delete(np.array([idx._next_label]))  # never issued -> error


def test_unrelated_delete_keeps_cached_entry_and_results(small_index, corpus):
    """The entry-cache regression (ISSUE 9 satellite): deleting vertices
    OTHER than the entry must leave the cached entry slot in place — no
    O(N·D) medoid recompute, and no silent reseed of later searches from
    a different vertex.  The delete set is chosen so the live-set medoid
    actually moves (the pre-fix blanket `_entry = None` would therefore
    have changed which vertex seeds the beam), and the post-delete search
    is pinned bitwise to the cached-entry traversal."""
    _, q, _ = corpus
    idx = _fresh_index(small_index)
    idx.search(q[:4], k=K, ef=EF)                  # warm the entry cache
    e = int(idx._entry)
    x600, _ = small_index
    # keep only the entry plus the 99 vertices FARTHEST from it (83%
    # tombstones, under the 0.9 auto-compact threshold): the live
    # centroid lands inside the far cluster, so a recomputed live-medoid
    # provably differs from the cached one
    dist_e = np.linalg.norm(np.asarray(x600) - np.asarray(x600)[e], axis=1)
    keep = set(np.argsort(dist_e)[-99:].tolist()) | {e}
    dels = np.array(sorted(set(range(600)) - keep))
    live = np.ones(600, bool)
    live[dels] = False
    e_live = int(medoid(x600, jnp.asarray(live)))
    assert e_live != e, "delete set must move the live medoid"
    idx.delete(dels)
    assert idx._entry is not None and int(idx._entry) == e
    got = idx.search(q, k=K, ef=EF)
    want = search(x600, idx.pool.ids[:600], q, k=K, ef=EF,
                  entry=jnp.int32(e), valid=idx.valid[:600])
    np.testing.assert_array_equal(np.asarray(got.ids),
                                  np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(want.dists))


def test_deleting_the_entry_slot_invalidates_cache(small_index, corpus):
    """The other half of the contract: when the tombstone DOES hit the
    cached entry slot, the cache must drop — the next search reseeds
    from the live medoid instead of a dead vertex."""
    _, q, _ = corpus
    idx = _fresh_index(small_index)
    idx.search(q[:4], k=K, ef=EF)
    e = int(idx._entry)
    idx.delete(np.array([e]))
    assert idx._entry is None
    res = idx.search(q, k=K, ef=EF)                # reseeds, still works
    assert int(idx._entry) != e
    assert bool(idx.valid[int(idx._entry)])
    got = set(np.asarray(res.ids).ravel().tolist())
    assert e not in got


def test_insert_into_emptied_index_rebootstraps():
    """Delete everything, compact to size 0, insert again: the batch must
    seed off itself (no live graph exists) and stay fully searchable — a
    sliding-window corpus that turns over completely must recover."""
    x = synthetic.make_preset(jax.random.PRNGKey(9), "tiny", 120)
    pool = grnnd.build_graph(jax.random.PRNGKey(10), x[:100],
                             grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2,
                                               pairs_per_vertex=8))
    idx = DynamicIndex(x[:100], pool,
                       DynamicConfig(refine_rounds=2, compact_threshold=0.5,
                                     seed_k=6))
    idx.delete(np.arange(100))            # auto-compacts to size 0
    assert idx.size == 0
    labs = idx.insert(x[100:120])
    assert labs.tolist() == list(range(100, 120))
    q = synthetic.queries_from(jax.random.PRNGKey(11), x[100:120], 16)
    res = idx.search(q, k=5, ef=16)
    rec = recall.recall_at_k(res.ids, idx.exact_knn(q, 5))
    assert rec >= 0.8, rec                # the new corpus is reachable


def test_insert_after_compact_roundtrip(small_index, corpus):
    """Labels survive the full mutate/compact/mutate cycle."""
    x, q, _ = corpus
    idx = _fresh_index(small_index)
    idx.delete(np.arange(100))
    idx.compact()
    labs = idx.insert(x[600:650])
    assert labs.tolist() == list(range(600, 650))
    res = idx.search(q[:16], k=K, ef=EF)
    got = set(np.asarray(res.ids).ravel().tolist())
    assert not (got & set(range(100)))   # deleted stay gone
    rec = recall.recall_at_k(res.ids, idx.exact_knn(q[:16], K))
    assert rec >= 0.80, rec


def test_all_dead_index_returns_empty_results():
    """Tombstoning everything must yield all -1 ids / +inf dists — in
    particular the (dead) entry vertex is dropped by the first beam merge,
    never returned (core/search.py entry guard)."""
    x = synthetic.make_preset(jax.random.PRNGKey(6), "tiny", 64)
    ids = jax.random.randint(jax.random.PRNGKey(7), (64, 8), -1, 64)
    q = synthetic.queries_from(jax.random.PRNGKey(8), x, 4)
    res = search(x, ids, q, k=5, ef=16, valid=jnp.zeros((64,), bool))
    assert bool(jnp.all(res.ids == -1))
    assert not bool(jnp.any(jnp.isfinite(res.dists)))
    # a single survivor is the only thing ever returned
    res1 = search(x, ids, q, k=5, ef=16,
                  valid=jnp.zeros((64,), bool).at[7].set(True))
    assert set(np.asarray(res1.ids).ravel().tolist()) <= {-1, 7}


# ---------------------------------------------------------------------------
# optimized layout on the dynamic index (ISSUE 6): external-label stability
# ---------------------------------------------------------------------------

def _paired_indices(small_index, order="bfs"):
    """The same corpus/graph as two DynamicIndexes: raw slot layout vs
    `DynamicConfig(layout=...)` (renumbered at construction and after
    every compaction)."""
    x, pool = small_index
    plain = DynamicIndex(x, pool, DynamicConfig(refine_rounds=1,
                                                compact_threshold=0.9))
    laid = DynamicIndex(x, pool, DynamicConfig(refine_rounds=1,
                                               compact_threshold=0.9,
                                               layout=order))
    return plain, laid


def test_layout_index_bitwise_equal_at_construction(small_index, corpus):
    """Before any mutation the layout is pure renumbering: label-space
    results are bitwise identical to the raw-slot index."""
    _, q, _ = corpus
    plain, laid = _paired_indices(small_index)
    a = plain.search(q, k=K, ef=EF)
    b = laid.search(q, k=K, ef=EF)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_layout_index_label_stability_under_churn(small_index, corpus):
    """Insert/delete on an optimized index issues the SAME external labels
    as the raw-slot index, deleted labels stay gone, and recall against
    the live ground truth holds — the layout must be invisible to the
    label-space API across mutations."""
    x, q, _ = corpus
    plain, laid = _paired_indices(small_index)
    for rnd in range(3):
        lo = 600 + 30 * rnd
        la = plain.insert(x[lo:lo + 30])
        lb = laid.insert(x[lo:lo + 30])
        np.testing.assert_array_equal(la, lb)       # identical new labels
        dels = np.arange(5 * rnd, 600, 37)
        assert plain.delete(dels) == laid.delete(dels)
    def live(idx):
        v = np.asarray(idx.valid[:idx.size])
        return set(np.asarray(idx.labels[:idx.size])[v].tolist())

    assert live(plain) == live(laid)
    np.testing.assert_array_equal(np.asarray(plain.exact_knn(q, K)),
                                  np.asarray(laid.exact_knn(q, K)))
    res = laid.search(q, k=K, ef=EF)
    got = set(np.asarray(res.ids).ravel().tolist()) - {-1}
    assert got <= live(laid)                        # deleted never returned
    rec = recall.recall_at_k(res.ids, laid.exact_knn(q, K))
    assert rec >= 0.80, rec


def test_layout_compact_reoptimizes_exactly(small_index, corpus):
    """compact() on a layout-configured index re-runs the layout pass on
    the survivors — and must STILL preserve label-space results exactly,
    the test_compact_preserves_search_exactly contract through a second
    renumbering."""
    _, q, _ = corpus
    _, laid = _paired_indices(small_index)
    rng = np.random.default_rng(12)
    dels = rng.choice(600, size=200, replace=False)
    laid.delete(np.sort(dels))
    before = laid.search(q, k=K, ef=EF)
    gt_before = laid.exact_knn(q, K)
    laid.compact()
    assert laid.cfg.layout == "bfs"                 # sticky re-optimize
    assert laid.size == laid.n_live == 400
    after = laid.search(q, k=K, ef=EF)
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))
    np.testing.assert_array_equal(np.asarray(before.dists),
                                  np.asarray(after.dists))
    np.testing.assert_array_equal(np.asarray(gt_before),
                                  np.asarray(laid.exact_knn(q, K)))


def test_optimize_layout_is_idempotent_bitwise(small_index, corpus):
    """Re-running the layout pass on an already-optimized index permutes
    slots again but may never change label-space results."""
    _, q, _ = corpus
    _, laid = _paired_indices(small_index, order="hub")
    a = laid.search(q, k=K, ef=EF)
    laid.optimize_layout("hub")
    b = laid.search(q, k=K, ef=EF)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


# ---------------------------------------------------------------------------
# distributed routing: owner-shard insert == single-device insert
# ---------------------------------------------------------------------------

def test_sharded_apply_requests_matches_single_device(small_index):
    from repro.core.distributed import sharded_apply_requests
    x, pool = small_index
    mesh = jax.make_mesh((1,), ("data",))
    kd, ks = jax.random.split(jax.random.PRNGKey(7))
    req = Requests(
        dst=jax.random.randint(kd, (64,), -1, 600),
        src=jax.random.randint(ks, (64,), 0, 600),
        dist=jnp.abs(jax.random.normal(jax.random.PRNGKey(8), (64,))),
    )
    want = insert_requests(pool, req)
    got = sharded_apply_requests(mesh, ("data",), pool, req)
    np.testing.assert_array_equal(np.asarray(want.ids), np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(want.dists),
                                  np.asarray(got.dists))


@pytest.mark.slow
def test_sharded_apply_requests_multi_shard_parity():
    """4 shards, adversarial requests: true self-inserts (dst == src, must
    drop) and cross-space collisions (global src == shard-LOCAL dst row,
    must keep) — the self filter has to run in global id space before
    re-basing (core/distributed._filter_to_local)."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core import grnnd
        from repro.core.distributed import sharded_apply_requests
        from repro.core.pools import Requests, insert_requests
        from repro.data import synthetic

        x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 256)
        cfg = grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2, pairs_per_vertex=8)
        pool = grnnd.build_graph(jax.random.PRNGKey(1), x, cfg)
        kd, ks = jax.random.split(jax.random.PRNGKey(2))
        dst = jax.random.randint(kd, (200,), -1, 256)
        src = jax.random.randint(ks, (200,), 0, 64)  # all < n_loc: collisions
        dst = dst.at[:20].set(src[:20])              # true self-inserts
        req = Requests(dst=dst, src=src,
                       dist=jnp.abs(jax.random.normal(
                           jax.random.PRNGKey(3), (200,))))
        want = insert_requests(pool, req)
        mesh = jax.make_mesh((4,), ("data",))
        got = sharded_apply_requests(mesh, ("data",), pool, req)
        same = (np.array_equal(np.asarray(want.ids), np.asarray(got.ids))
                and np.array_equal(np.asarray(want.dists),
                                   np.asarray(got.dists)))
        print("RESULT", int(same))
    """)
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    assert line == "RESULT 1", proc.stdout
