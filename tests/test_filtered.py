"""Filtered-search suite (PR CI fast tier): ISSUE 5 acceptance contracts.

Five contracts:

  * **filter-operand parity** — the fused `search_expand` kernel
    (interpret mode) matches the ref.py oracle bitwise WITH the predicate
    operands, on all three precision rungs (fp32/bf16/int8), per the same
    common-jit-context convention as the `valid`-mask suite in
    tests/test_dynamic.py;
  * **trace cleanliness** — the unfiltered path compiles WITHOUT the
    filter operands (trace-time flag, same idiom as `masked`): asserted
    on the pallas_call equation's operand/output counts in the jaxpr;
  * **route-through semantics** — a filtered-out vertex stays traversable
    (the only path to an allowed vertex may run through disallowed ones),
    in contrast to the tombstone mask, which severs it;
  * **saturating-ef exactness** — with ef >= N the filtered result set
    equals brute force over each query's allowed subset (hypothesis
    property over label assignments/predicates, plus fixed-seed cases
    that run without hypothesis installed);
  * **predicate invariant** — every returned id satisfies its query's
    predicate, across single-label, multi-label, and packed predicate
    forms, and across visited representations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grnnd, labels as L, vecstore as VS
from repro.core.search import _table_insert, search
from repro.data import synthetic
from repro.kernels import ops, ref
from repro.kernels.search_expand import search_expand_pallas
from conftest import optional_hypothesis

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity


given, settings, st = optional_hypothesis()


# ---------------------------------------------------------------------------
# label packing
# ---------------------------------------------------------------------------

def test_pack_ids_roundtrip():
    ids = jnp.array([0, 31, 32, 63, 64, -1, 5], jnp.int32)
    words = L.pack_ids(ids, 70)
    assert words.shape == (7, 3)
    w = np.asarray(words)
    for i, v in enumerate(np.asarray(ids)):
        if v < 0:
            assert not w[i].any()
        else:
            assert (w[i, v // 32] >> (v % 32)) & 1
            assert bin(int(np.uint32(w[i, v // 32]))).count("1") == 1


def test_pack_bits_matches_pack_ids_on_onehot():
    ids = jnp.arange(40, dtype=jnp.int32)
    member = jnp.zeros((40, 40), bool).at[jnp.arange(40), ids].set(True)
    np.testing.assert_array_equal(np.asarray(L.pack_bits(member)),
                                  np.asarray(L.pack_ids(ids, 40)))


def test_query_words_forms_agree():
    """(Q,) id, (Q, L) bool, and (Q, W) packed predicates all normalize to
    the same operand."""
    idsq = jnp.array([3, 17, 0], jnp.int32)
    w = L.n_words(20)
    packed = L.pack_ids(idsq, 20)
    member = jnp.zeros((3, 20), bool).at[jnp.arange(3), idsq].set(True)
    np.testing.assert_array_equal(np.asarray(L.query_words(idsq, w)),
                                  np.asarray(packed))
    np.testing.assert_array_equal(np.asarray(L.query_words(member, w)),
                                  np.asarray(packed))
    np.testing.assert_array_equal(np.asarray(L.query_words(packed, w)),
                                  np.asarray(packed))


def test_encode_labels_freezes_space():
    store = L.encode_labels(jnp.array([0, 2, 5], jnp.int32), 33)
    assert store.w == 2 and store.capacity == 64
    with pytest.raises(AssertionError):
        L.encode_labels(jnp.array([40], jnp.int32), 33)


# ---------------------------------------------------------------------------
# kernel/oracle bitwise parity with the filter operand, per precision rung
# ---------------------------------------------------------------------------

def _expand_case(seed, qn, r, n, d, h, n_labels, sel):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(k, 7)
    x = synthetic.vector_dataset(k1, n, d, n_clusters=max(2, n // 16))
    q = synthetic.queries_from(k2, x, qn)
    nbrs = jax.random.randint(k3, (qn, r), -1, n)
    tab = _table_insert(
        jnp.full((qn, h), -1, jnp.int32),
        jnp.where(jax.random.bernoulli(k4, 0.5, (qn, r)), nbrs, -1))
    valid = jax.random.bernoulli(k5, 0.8, (n,))
    store = L.encode_labels(jax.random.randint(k6, (n,), 0, n_labels),
                            n_labels)
    fw = L.random_query_filters(k7, qn, n_labels, sel)
    return x, q, nbrs, tab, valid, store.words, fw


@pytest.mark.parametrize("precision", VS.PRECISIONS)
@pytest.mark.parametrize("qn,r,n,d,h,n_labels,sel", [
    (8, 10, 64, 12, 32, 40, 0.2),
    (5, 7, 50, 33, 16, 70, 0.05),   # D not lane-aligned, 3 bitset words
    (4, 8, 40, 16, 1, 8, 0.5),      # H = 1: the dense-path dummy table
    (3, 6, 30, 8, 3, 100, 0.01),    # H < PROBES, 1-label predicates
])
def test_expand_filter_matches_oracle(precision, qn, r, n, d, h,
                                      n_labels, sel):
    x, q, nbrs, tab, valid, vw, fw = _expand_case(
        23, qn, r, n, d, h, n_labels, sel)
    vs = VS.encode(x, precision)
    got = search_expand_pallas(vs.data, q, nbrs, tab, valid,
                               vs.scale, vs.offset, vw, fw, interpret=True)
    want = jax.jit(ref.search_expand_ref)(vs.data, q, nbrs, tab, valid,
                                          vs.scale, vs.offset, vw, fw)
    assert len(got) == len(want) == 4
    for name, g, w in zip(("ids", "dists", "fresh", "allowed"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{precision}/{name}")


def test_expand_filter_route_through_outputs():
    """The predicate must not perturb ids/dists/fresh — only add `allowed`."""
    x, q, nbrs, tab, valid, vw, fw = _expand_case(29, 6, 8, 48, 16, 32,
                                                  20, 0.2)
    plain = search_expand_pallas(x, q, nbrs, tab, valid, interpret=True)
    filt = search_expand_pallas(x, q, nbrs, tab, valid, None, None, vw, fw,
                                interpret=True)
    assert len(plain) == 3 and len(filt) == 4
    for g, w in zip(plain, filt[:3]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # allowed <= live, and matches the label store exactly
    allowed = np.asarray(filt[3])
    ids = np.asarray(filt[0])
    want = np.asarray(L.allowed_mask(jnp.asarray(ids), fw, vw))
    np.testing.assert_array_equal(allowed, want)


# ---------------------------------------------------------------------------
# trace cleanliness: unfiltered paths compile WITHOUT the filter operand
# ---------------------------------------------------------------------------

def _pallas_eqns(jaxpr):
    out = []
    for e in jaxpr.eqns:
        if e.primitive.name == "pallas_call":
            out.append(e)
        for v in e.params.values():
            if hasattr(v, "jaxpr"):
                out.extend(_pallas_eqns(v.jaxpr))
            elif hasattr(v, "eqns"):
                out.extend(_pallas_eqns(v))
    return out


def test_unfiltered_trace_has_no_filter_operand():
    """The `filtered` flag is trace-time, same idiom as `masked`: the
    unfiltered kernel trace carries neither predicate operand nor the
    `allowed` output; the filtered trace carries exactly both operands
    and one extra output."""
    x = synthetic.vector_dataset(jax.random.PRNGKey(0), 40, 16)
    q = x[:4]
    nbrs = jnp.zeros((4, 6), jnp.int32)
    tab = jnp.full((4, 8), -1, jnp.int32)
    vw = L.encode_labels(jnp.zeros((40,), jnp.int32), 5).words
    fw = L.pack_ids(jnp.zeros((4,), jnp.int32), 5)

    plain = jax.make_jaxpr(
        lambda *a: search_expand_pallas(*a, interpret=True))(x, q, nbrs, tab)
    filt = jax.make_jaxpr(
        lambda *a: search_expand_pallas(a[0], a[1], a[2], a[3], None, None,
                                        None, a[4], a[5], interpret=True)
    )(x, q, nbrs, tab, vw, fw)
    (ep,), (ef_,) = _pallas_eqns(plain.jaxpr), _pallas_eqns(filt.jaxpr)
    assert len(ef_.invars) == len(ep.invars) + 2, (
        len(ep.invars), len(ef_.invars))
    assert len(ef_.outvars) == len(ep.outvars) + 1

    # end-to-end: the full `search` trace shows the same structure — every
    # per-step pallas expansion carries exactly 2 more operands and 1 more
    # output under a filter, and none of them exist without one
    g = jnp.zeros((40, 6), jnp.int32)
    with ops.backend("interpret"):
        sp = jax.make_jaxpr(
            lambda xx, gg, qq: search(xx, gg, qq, k=2, ef=4,
                                      entry=jnp.int32(0)))(x, g, q)
        sf = jax.make_jaxpr(
            lambda xx, gg, qq, v, f: search(xx, gg, qq, k=2, ef=4,
                                            entry=jnp.int32(0), labels=v,
                                            filter=f, overfetch=2)
        )(x, g, q, vw, fw)
    ep2 = [e for e in _pallas_eqns(sp.jaxpr)
           if len(e.outvars) in (3, 4)]       # the expand kernels
    ef2 = [e for e in _pallas_eqns(sf.jaxpr) if len(e.outvars) in (3, 4)]
    assert ep2 and ef2
    assert all(len(e.outvars) == 3 for e in ep2)
    assert all(len(e.outvars) == 4 for e in ef2)
    assert all(len(e.invars) == len(ep2[0].invars) + 2 for e in ef2)


# ---------------------------------------------------------------------------
# route-through semantics vs the exclude (tombstone) mask
# ---------------------------------------------------------------------------

def test_route_through_vs_exclude():
    """Chain graph 0-1-2-3 with 1, 2 filtered out: the filter must ROUTE
    THROUGH them to return 3; the tombstone mask on the same vertices must
    sever the path (3 unreachable) — the two masks are different features.
    """
    xs = jnp.array([[0., 0.], [1., 0.], [2., 0.], [3., 0.]])
    g = jnp.array([[1, -1], [0, 2], [1, 3], [2, -1]], jnp.int32)
    q = jnp.array([[3.1, 0.]])
    store = L.encode_labels(jnp.array([0, 1, 1, 0], jnp.int32), 2)
    fw = L.pack_ids(jnp.array([0], jnp.int32), 2)

    res = search(xs, g, q, k=2, ef=4, entry=jnp.int32(0),
                 labels=store, filter=fw)
    assert np.asarray(res.ids)[0].tolist() == [3, 0]

    sev = search(xs, g, q, k=2, ef=4, entry=jnp.int32(0),
                 valid=jnp.array([True, False, False, True]))
    assert np.asarray(sev.ids)[0].tolist() == [0, -1]


def test_filter_composes_with_tombstones():
    """valid excludes from traversal; filter excludes from results only —
    a returned id must be live AND allowed."""
    x = synthetic.make_preset(jax.random.PRNGKey(3), "tiny", 150)
    pool = grnnd.build_graph(jax.random.PRNGKey(4), x,
                             grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2,
                                               pairs_per_vertex=8))
    q = synthetic.queries_from(jax.random.PRNGKey(5), x, 12)
    valid = jax.random.bernoulli(jax.random.PRNGKey(6), 0.7, (150,))
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(7), (150,), 0, 10), 10)
    fw = L.random_query_filters(jax.random.PRNGKey(8), 12, 10, 0.3)
    res = search(x, pool.ids, q, k=5, ef=32, valid=valid,
                 labels=store, filter=fw)
    ids = np.asarray(res.ids)
    ok = np.asarray(L.allowed_mask(jnp.asarray(ids), fw, store.words))
    live = np.asarray(valid)[np.clip(ids, 0, None)]
    assert ((ids < 0) | (ok & live)).all()


@pytest.mark.parametrize("visited", ["dense", "hashed"])
def test_predicate_invariant_all_visited_modes(visited):
    x = synthetic.make_preset(jax.random.PRNGKey(10), "tiny", 200)
    pool = grnnd.build_graph(jax.random.PRNGKey(11), x,
                             grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3,
                                               pairs_per_vertex=16))
    q = synthetic.queries_from(jax.random.PRNGKey(12), x, 16)
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(13), (200,), 0, 25), 25)
    fw = L.random_query_filters(jax.random.PRNGKey(14), 16, 25, 0.2)
    res = search(x, pool.ids, q, k=10, ef=48, visited=visited,
                 labels=store, filter=fw)
    assert L.predicate_fraction(res.ids, fw, store.words) == 1.0
    gt = L.filtered_brute_force(x, q, fw, store.words, 10)
    assert L.filtered_recall_at_k(res.ids, gt) >= 0.9


def test_multi_label_store_end_to_end():
    """Vertices carrying SETS of labels (encode_label_sets): a result is
    allowed iff its label set intersects the query's allowed set."""
    n, n_labels = 150, 16
    x = synthetic.make_preset(jax.random.PRNGKey(60), "tiny", n)
    pool = grnnd.build_graph(jax.random.PRNGKey(61), x,
                             grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2,
                                               pairs_per_vertex=8))
    q = synthetic.queries_from(jax.random.PRNGKey(62), x, 10)
    member = jax.random.bernoulli(jax.random.PRNGKey(63), 0.15,
                                  (n, n_labels))
    store = L.encode_label_sets(member)
    assert store.labels is None  # multi-label: the bitset is the identity
    fw = L.random_query_filters(jax.random.PRNGKey(64), 10, n_labels, 0.2)
    res = search(x, pool.ids, q, k=5, ef=32, labels=store, filter=fw)
    ids = np.asarray(res.ids)
    mem = np.asarray(member)
    allow = np.asarray(fw)
    for qi in range(10):
        # which labels does query qi allow?
        lab_ok = [(allow[qi, l // 32] >> (l % 32)) & 1
                  for l in range(n_labels)]
        for v in ids[qi]:
            if v >= 0:
                assert any(mem[v, l] and lab_ok[l]
                           for l in range(n_labels)), (qi, v)
    gt = L.filtered_brute_force(x, q, fw, store.words, 5)
    assert L.filtered_recall_at_k(res.ids, gt) >= 0.9


def test_filtered_backend_parity_end_to_end():
    """Interpret-backend filtered search (fused kernel) == ref-backend,
    bitwise, mirroring test_search_parity.test_search_backend_parity."""
    x = synthetic.make_preset(jax.random.PRNGKey(20), "tiny", 120)
    pool = grnnd.build_graph(jax.random.PRNGKey(21), x,
                             grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2,
                                               pairs_per_vertex=8))
    q = synthetic.queries_from(jax.random.PRNGKey(22), x, 8)
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(23), (120,), 0, 12), 12)
    fw = L.random_query_filters(jax.random.PRNGKey(24), 8, 12, 0.3)
    with ops.backend("ref"):
        a = search(x, pool.ids, q, k=5, ef=16, labels=store, filter=fw)
    with ops.backend("interpret"):
        b = search(x, pool.ids, q, k=5, ef=16, labels=store, filter=fw)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


# ---------------------------------------------------------------------------
# saturating ef: the filtered result set == brute force over the allowed set
# ---------------------------------------------------------------------------

def _reachable(graph_ids: np.ndarray, entry: int) -> np.ndarray:
    """BFS over the directed neighbor graph (the set a saturating-ef beam
    visits exactly)."""
    n = graph_ids.shape[0]
    seen = np.zeros((n,), bool)
    stack = [entry]
    seen[entry] = True
    while stack:
        v = stack.pop()
        for u in graph_ids[v]:
            if u >= 0 and not seen[u]:
                seen[u] = True
                stack.append(int(u))
    return seen


def _check_saturating_equals_brute_force(label_seed: int, filter_seed: int,
                                         sel: float):
    n, n_labels = 160, 24
    x = synthetic.make_preset(jax.random.PRNGKey(30), "tiny", n)
    pool = grnnd.build_graph(jax.random.PRNGKey(31), x,
                             grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3,
                                               pairs_per_vertex=16))
    q = synthetic.queries_from(jax.random.PRNGKey(32), x, 12)
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(label_seed), (n,), 0,
                           n_labels), n_labels)
    fw = L.random_query_filters(jax.random.PRNGKey(filter_seed), 12,
                                n_labels, sel)

    # the equality claim is about TRAVERSABLE vertices: restrict the truth
    # to the entry's reachable set (on these builds it is virtually always
    # everything; the guard keeps the property honest if it is not)
    from repro.core.search import medoid
    entry = int(medoid(x))
    reach = _reachable(np.asarray(pool.ids), entry)
    vw = jnp.where(jnp.asarray(reach)[:, None], store.words, 0)

    res = search(x, pool.ids, q, k=10, ef=n, max_steps=2 * n,
                 labels=store, filter=fw)
    gt = L.filtered_brute_force(x, q, fw, vw, 10)
    got = np.sort(np.asarray(res.ids), axis=1)
    want = np.sort(np.asarray(gt), axis=1)
    np.testing.assert_array_equal(got, want)
    assert L.filtered_recall_at_k(res.ids, gt) == 1.0


@pytest.mark.parametrize("label_seed,filter_seed,sel", [
    (40, 41, 0.05), (42, 43, 0.2), (44, 45, 0.6)])
def test_saturating_ef_equals_brute_force(label_seed, filter_seed, sel):
    _check_saturating_equals_brute_force(label_seed, filter_seed, sel)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_saturating_ef_equals_brute_force_property(data):
    """Hypothesis sweep over label assignments and predicate draws: at
    saturating ef, filtered search may never return anything other than
    the exact allowed-subset brute force."""
    label_seed = data.draw(st.integers(0, 2**16))
    filter_seed = data.draw(st.integers(0, 2**16))
    sel = data.draw(st.sampled_from([0.04, 0.1, 0.25, 0.5, 1.0]))
    _check_saturating_equals_brute_force(label_seed, filter_seed, sel)


# ---------------------------------------------------------------------------
# over-fetch policy
# ---------------------------------------------------------------------------

def test_overfetch_widens_working_ef():
    """At low selectivity, ef=k alone starves the result heap; the default
    over-fetch floor (4k) must recover a full result set when enough
    allowed vertices exist near the query."""
    x = synthetic.make_preset(jax.random.PRNGKey(50), "tiny", 200)
    pool = grnnd.build_graph(jax.random.PRNGKey(51), x,
                             grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3,
                                               pairs_per_vertex=16))
    q = synthetic.queries_from(jax.random.PRNGKey(52), x, 16)
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(53), (200,), 0, 4), 4)
    fw = L.random_query_filters(jax.random.PRNGKey(54), 16, 4, 0.25)
    starved = search(x, pool.ids, q, k=10, ef=10, labels=store, filter=fw,
                     overfetch=1)
    wide = search(x, pool.ids, q, k=10, ef=10, labels=store, filter=fw)
    n_starved = int((np.asarray(starved.ids) >= 0).sum())
    n_wide = int((np.asarray(wide.ids) >= 0).sum())
    assert n_wide >= n_starved
    gt = L.filtered_brute_force(x, q, fw, store.words, 10)
    assert (L.filtered_recall_at_k(wide.ids, gt)
            >= L.filtered_recall_at_k(starved.ids, gt))
