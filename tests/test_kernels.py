"""Kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracles.

Sweeps shapes and dtypes per the brief; hypothesis property tests cover the
merge semantics (capacity, uniqueness, distance ordering).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.kernels import ref
from repro.kernels.pairwise_l2 import pairwise_sqdist_pallas, rowwise_sqdist_pallas
from repro.kernels.topr_merge import topr_merge_pallas

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity



@pytest.mark.parametrize("m,n,d", [
    (4, 4, 8), (17, 33, 12), (128, 128, 128), (130, 70, 200),
    (1, 256, 960), (64, 64, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_matches_ref(m, n, d, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, d), dtype)
    y = jax.random.normal(ky, (n, d), dtype)
    got = pairwise_sqdist_pallas(x, y, bm=32, bn=32, bk=128, interpret=True)
    want = ref.pairwise_sqdist_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("m,d", [(3, 5), (64, 128), (100, 960), (257, 31)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowwise_matches_ref(m, d, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (m, d), dtype)
    y = jax.random.normal(ky, (m, d), dtype)
    got = rowwise_sqdist_pallas(x, y, bm=32, bk=128, interpret=True)
    want = ref.rowwise_sqdist_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


def test_pairwise_self_distance_zero():
    x = jax.random.normal(jax.random.PRNGKey(2), (40, 64))
    d = pairwise_sqdist_pallas(x, x, bm=16, bn=16, bk=64, interpret=True)
    np.testing.assert_allclose(jnp.diag(d), np.zeros(40), atol=1e-4)


@pytest.mark.parametrize("b,w,r", [(4, 16, 4), (10, 40, 8), (8, 130, 32), (1, 8, 8)])
def test_topr_merge_matches_ref(b, w, r):
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    # ids with duplicates and empties
    ids = jax.random.randint(k1, (b, w), -1, w // 2 + 2)
    dists = jnp.abs(jax.random.normal(k2, (b, w)))
    # the same id must carry the same distance (it is d(owner, id))
    lut = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (w + 2,)))
    dists = jnp.where(ids >= 0, lut[jnp.clip(ids, 0)], jnp.inf)
    gi, gd = topr_merge_pallas(ids, dists, r, br=4, interpret=True)
    wi, wd = ref.topr_merge_ref(ids, dists, r)
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_allclose(gd, wd, rtol=1e-6)


@settings(deadline=None, max_examples=25)
@given(
    b=st.integers(1, 6),
    w=st.integers(1, 24),
    r=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_topr_merge_properties(b, w, r, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    ids = np.asarray(jax.random.randint(k1, (b, w), -1, 10))
    lut = np.asarray(jnp.abs(jax.random.normal(k2, (12,))))
    dists = np.where(ids >= 0, lut[np.clip(ids, 0, None)], np.inf)

    oi, od = ref.topr_merge_ref(jnp.asarray(ids), jnp.asarray(dists), r)
    oi, od = np.asarray(oi), np.asarray(od)

    for row in range(b):
        valid = oi[row][oi[row] >= 0]
        # uniqueness
        assert len(valid) == len(set(valid.tolist()))
        # capacity
        assert len(valid) <= r
        # ascending distances among valid entries
        dv = od[row][oi[row] >= 0]
        assert np.all(np.diff(dv) >= -1e-7)
        # completeness: nothing closer was left out
        in_ids = set(i for i in ids[row].tolist() if i >= 0)
        left_out = in_ids - set(valid.tolist())
        if len(valid) == r and left_out:
            worst_kept = dv.max() if len(dv) else np.inf
            best_left = min(lut[i] for i in left_out)
            assert best_left >= worst_kept - 1e-7
