"""Gather-fused distance kernel vs gathered-rowwise oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gather_l2 import gather_sqdist_pallas

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity



@pytest.mark.parametrize("n,d,m", [(64, 8, 16), (200, 128, 64), (50, 33, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_sqdist_matches_ref(n, d, m, dtype):
    key = jax.random.PRNGKey(0)
    kx, ki, kj = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d), dtype)
    ni = jax.random.randint(ki, (m,), 0, n)
    nj = jax.random.randint(kj, (m,), 0, n)
    got = gather_sqdist_pallas(x, ni, nj, interpret=True)
    want = ref.rowwise_sqdist_ref(x[ni], x[nj])
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


def test_gather_sqdist_self_zero():
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    idx = jnp.arange(10)
    got = gather_sqdist_pallas(x, idx, idx, interpret=True)
    np.testing.assert_allclose(got, np.zeros(10), atol=1e-6)
