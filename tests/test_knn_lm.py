"""kNN-LM retrieval-in-the-loop suite (DESIGN.md §14).

Locks the production datastore path to the array-backed reference and the
serving hooks to their contracts:

  * **fp32 parity** — a `DynamicDatastore` (DynamicIndex-backed) and the
    frozen array-backed `knn_logits` produce BITWISE-equal next-token
    log-distributions when the traversal is pinned to the same entry and
    validity view (same graph, same kernels, same vote);
  * **quantized memorization** — int8 traversal + fp32 rescore keeps the
    memorization accuracy of fp32 (within 1pt), and the host-cold rescore
    tier changes nothing bitwise;
  * **streaming decode** — pairs inserted DURING a generation (the
    `token_hook` path) are retrievable by later steps of the same
    generation, from a datastore that started empty;
  * **hook contracts** — `ServeEngine(logit_hook=)` passes
    ``(lm_logits, hidden)`` (the seed called it with one argument and
    crashed on the first decode step: the regression pin runs a real
    `make_logit_hook` through `generate`), and `return_hidden=True` is
    honored; the default `prefill`/`decode_step` tuples stay bitwise
    identical with the hidden-state plumbing in place;
  * **vote/fuse mass** — the kNN vote is a normalized log-distribution
    with true ``-inf`` support, so the fused distribution carries total
    mass exactly 1 at any vocab size, and no-support rows fall back to
    the pure LM.
"""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grnnd
from repro.retrieval import knn_lm
from repro.retrieval.knn_lm import DynamicDatastore

pytestmark = pytest.mark.kernel_parity

N, DIM, VOCAB = 240, 32, 128
K, EF = 8, 32
CFG = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)


@pytest.fixture(scope="module")
def pairs():
    x = jax.random.normal(jax.random.PRNGKey(0), (N, DIM), jnp.float32)
    toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (N,), 0, VOCAB), np.int32
    )
    return x, toks


@pytest.fixture(scope="module")
def array_store(pairs):
    x, toks = pairs
    return knn_lm.build_datastore(jax.random.PRNGKey(2), x, toks, CFG)


def _dyn(pairs, **kw):
    x, toks = pairs
    return DynamicDatastore.build(
        jax.random.PRNGKey(2), x, toks, VOCAB, build_cfg=CFG, k=K, ef=EF, **kw
    )


@pytest.fixture(scope="module")
def fp32_ds(pairs):
    return _dyn(pairs, precision="fp32")


@pytest.fixture(scope="module")
def int8_ds(pairs):
    return _dyn(pairs, precision="int8")


def _acc(ds_or_klp, x, toks):
    klp = ds_or_klp if isinstance(ds_or_klp, jnp.ndarray) else None
    if klp is None:
        klp = ds_or_klp.knn_log_probs(x)
    return float((jnp.argmax(klp, axis=-1) == jnp.asarray(toks)).mean())


# -- parity ---------------------------------------------------------------


def test_fp32_dynamic_matches_array_reference_bitwise(
    pairs, array_store, fp32_ds
):
    """Same graph + same traversal pins -> bitwise-equal vote output."""
    x, _ = pairs
    q = x[:64] + 0.05  # near-duplicate queries, off the exact keys
    got = fp32_ds.knn_log_probs(q)
    want = knn_lm.knn_logits(
        array_store,
        q,
        VOCAB,
        k=K,
        ef=EF,
        entry=fp32_ds.index.entry(),
        valid=fp32_ds.index.valid[:N],
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_rescore_keeps_memorization_accuracy(pairs, fp32_ds, int8_ds):
    """Queries AT stored keys must retrieve their own token: int8
    traversal + fp32 rescore stays within 1pt of fp32."""
    x, toks = pairs
    ref = _acc(fp32_ds, x, toks)
    assert ref >= 0.9, f"fp32 memorization accuracy only {ref}"
    assert _acc(int8_ds, x, toks) >= ref - 0.01


def test_host_tier_is_bitwise_equal_to_device(pairs, int8_ds):
    x, _ = pairs
    host = _dyn(pairs, precision="int8", tier="host")
    np.testing.assert_array_equal(
        np.asarray(host.knn_log_probs(x[:32])),
        np.asarray(int8_ds.knn_log_probs(x[:32])),
    )


def test_engine_routed_search_is_bitwise_equal(pairs):
    """attach_engine() swaps in the continuous-batching scheduler; the
    per-query results (and so the vote) must not change."""
    x, _ = pairs
    ds = _dyn(pairs, precision="fp32")
    direct = ds.knn_log_probs(x[:16])
    ds.attach_engine()
    try:
        routed = ds.knn_log_probs(x[:16])
    finally:
        ds._engine = None
    np.testing.assert_array_equal(np.asarray(routed), np.asarray(direct))


# -- streaming + filtering ------------------------------------------------


def test_streaming_inserts_retrieve_earlier_tokens():
    """A datastore that starts EMPTY and is fed via the token_hook path
    must serve retrieval for pairs written earlier in the same run."""
    ds = DynamicDatastore.empty(DIM, VOCAB, precision="fp32", k=4, ef=32)
    assert len(ds) == 0
    empty = ds.knn_log_probs(jnp.zeros((3, DIM)))
    assert not np.any(np.isfinite(np.asarray(empty)))

    stream = knn_lm.make_stream_hook(ds, insert_every=2)
    key = jax.random.PRNGKey(5)
    hs, ts = [], []
    for step in range(6):
        key, k1, k2 = jax.random.split(key, 3)
        h = jax.random.normal(k1, (8, DIM), jnp.float32)
        t = np.asarray(jax.random.randint(k2, (8,), 0, VOCAB), np.int32)
        stream(h, t)
        hs.append(h)
        ts.append(t)
    stream.flush()
    assert len(ds) == 48

    # the FIRST step's pairs, written while the graph was bootstrapping,
    # are retrievable now
    klp = ds.knn_log_probs(hs[0])
    assert _acc(klp, hs[0], ts[0]) >= 0.9


def test_source_filtered_retrieval_respects_provenance(pairs):
    """Disjoint token ranges per source: a filtered query may only ever
    see tokens from its allowed source."""
    x, _ = pairs
    half = N // 2
    toks = np.concatenate(
        [
            np.random.default_rng(0).integers(0, 50, half),
            np.random.default_rng(1).integers(50, 100, N - half),
        ]
    ).astype(np.int32)
    sources = (np.arange(N) >= half).astype(np.int32)
    ds = DynamicDatastore.build(
        jax.random.PRNGKey(2),
        x,
        toks,
        VOCAB,
        build_cfg=CFG,
        precision="fp32",
        sources=sources,
        n_sources=2,
        k=K,
        ef=EF,
    )
    q = x[half - 8 : half + 8]  # straddle the source boundary
    for src, lo, hi in ((0, 0, 50), (1, 50, 100)):
        klp = ds.knn_log_probs(q, filter=jnp.full((16,), src, jnp.int32))
        support = np.isfinite(np.asarray(klp))
        assert support.any(), "filtered search lost all support"
        voted = np.where(support.any(axis=0))[0]
        assert voted.min() >= lo and voted.max() < hi


def test_empty_labeled_datastore_bootstraps():
    """DynamicIndex used to crash on a zero-row corpus with vertex
    labels (vl.max() on an empty array); the streaming-from-empty
    filtered datastore needs it."""
    ds = DynamicDatastore.empty(DIM, VOCAB, precision="fp32", n_sources=2)
    assert len(ds) == 0
    h = jax.random.normal(jax.random.PRNGKey(6), (16, DIM), jnp.float32)
    t = np.arange(16, dtype=np.int32)
    ds.add(h, t, sources=np.repeat(np.arange(2, dtype=np.int32), 8))
    klp = ds.knn_log_probs(h[:8], filter=jnp.zeros((8,), jnp.int32))
    voted = np.where(np.isfinite(np.asarray(klp)).any(axis=0))[0]
    assert voted.max() < 8  # source 0 holds tokens 0..7 only


# -- vote / fuse mass -----------------------------------------------------


def test_vote_is_normalized_with_true_inf_support():
    ids = jnp.array([[0, 1, -1], [-1, -1, -1]])
    dists = jnp.array([[0.1, 0.4, 9.9], [9.9, 9.9, 9.9]])
    toks = jnp.array([[3, 5, 7], [0, 0, 0]])
    klp = knn_lm.vote_log_probs(ids, dists, toks, vocab=11)
    row = np.asarray(klp[0])
    assert np.isfinite(row[[3, 5]]).all()
    assert np.all(np.isneginf(np.delete(row, [3, 5])))
    np.testing.assert_allclose(np.exp(row[[3, 5]]).sum(), 1.0, rtol=1e-6)
    assert np.all(np.isneginf(np.asarray(klp[1])))  # no valid slot at all


def test_fuse_preserves_mass_at_large_vocab():
    """The seed's log(1e-9) clamp leaked ~lam*vocab*1e-9 of probability
    mass; with true -inf support the fused mass is exactly 1."""
    vocab = 50_000
    lm = jax.random.normal(jax.random.PRNGKey(7), (4, vocab))
    klp = jnp.full((4, vocab), -jnp.inf).at[:, :3].set(jnp.log(1 / 3))
    mass = np.exp(np.asarray(jax.nn.logsumexp(knn_lm.fuse(lm, klp, 0.3), -1)))
    np.testing.assert_allclose(mass, 1.0, rtol=1e-6)


def test_fuse_no_support_row_falls_back_to_pure_lm():
    lm = jax.random.normal(jax.random.PRNGKey(8), (2, 64))
    klp = jnp.full((2, 64), -jnp.inf).at[0, 5].set(0.0)
    fused = knn_lm.fuse(lm, klp, 0.5)
    np.testing.assert_array_equal(
        np.asarray(fused[1]), np.asarray(jax.nn.log_softmax(lm, -1)[1])
    )
    assert np.asarray(fused[0, 5]) > np.asarray(jax.nn.log_softmax(lm, -1))[0, 5]


# -- serving hooks (slow: compiles the transformer) -----------------------


@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_arch, reduced
    from repro.models import transformer as T

    cfg = reduced(get_arch("gemma3-1b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab, jnp.int32
    )
    return cfg, params, {"tokens": tokens}


@pytest.mark.slow
def test_default_prefill_decode_tuples_unchanged(lm_setup):
    """The hidden-state plumbing must not perturb logits-only callers:
    default tuples keep their arity and stay bitwise identical."""
    from repro.models import transformer as T

    cfg, params, batch = lm_setup
    out = T.prefill(params, cfg, batch, s_max=16, act_dtype=jnp.float32)
    out_h = T.prefill(
        params, cfg, batch, s_max=16, act_dtype=jnp.float32, return_hidden=True
    )
    assert len(out) == 3 and len(out_h) == 4
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out_h[0]))
    assert out_h[3].shape == (2, cfg.d_model)

    tok = jnp.argmax(out[0], -1).astype(jnp.int32)
    pos = jnp.full((2,), out[2], jnp.int32)
    dec = T.decode_step(params, cfg, out[1], tok, pos, act_dtype=jnp.float32)
    dec_h = T.decode_step(
        params, cfg, out_h[1], tok, pos, act_dtype=jnp.float32,
        return_hidden=True,
    )
    assert len(dec) == 2 and len(dec_h) == 3
    np.testing.assert_array_equal(np.asarray(dec[0]), np.asarray(dec_h[0]))
    # the returned hidden IS the state the logits were read from
    np.testing.assert_array_equal(
        np.asarray(T.lm_logits(params, cfg, dec_h[2][:, None])[:, 0]),
        np.asarray(dec_h[0]),
    )


@pytest.mark.slow
def test_real_logit_hook_runs_inside_generate(lm_setup):
    """S1 regression: the seed's engine called logit_hook(logits) and
    crashed with TypeError on the first decode step.  A REAL
    make_logit_hook (two-arg contract) must run end to end, the stream
    hook must grow the datastore during decode, and return_hidden=True
    must be honored (it was silently ignored)."""
    from repro.serve.engine import ServeEngine

    cfg, params, batch = lm_setup
    keys = jax.random.normal(
        jax.random.PRNGKey(3), (N, cfg.d_model), jnp.float32
    )
    toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(4), (N,), 0, cfg.vocab), np.int32
    )
    ds = DynamicDatastore.build(
        jax.random.PRNGKey(2), keys, toks, cfg.vocab,
        build_cfg=CFG, precision="fp32", k=4, ef=32,
    )
    calls = []
    fuse_hook = knn_lm.make_logit_hook(ds, lam=0.3)

    def spy(lm_logits, hidden):
        calls.append((lm_logits.shape, hidden.shape))
        return fuse_hook(lm_logits, hidden)

    stream = knn_lm.make_stream_hook(ds, insert_every=2)
    eng = ServeEngine(
        cfg, params, s_max=16, act_dtype=jnp.float32,
        logit_hook=spy, token_hook=stream,
    )
    # the dead `key` arg is gone from the decode signature (S3)
    assert "key" not in inspect.signature(eng._decode_impl).parameters

    n0 = len(ds)
    out = eng.generate(batch, max_new_tokens=4, return_hidden=True)
    stream.flush()
    assert out["tokens"].shape == (2, 4)
    assert out["hidden"].shape == (2, 4, cfg.d_model)
    assert calls == [((2, cfg.vocab), (2, cfg.d_model))] * 4
    assert len(ds) == n0 + 8  # 4 steps x batch 2 streamed in
    # hidden[:, t] is the state tokens[:, t] was sampled from: re-fusing
    # outside the engine reproduces the greedy choice
    klp = ds.knn_log_probs(out["hidden"][:, 0])
    assert klp.shape == (2, cfg.vocab)
