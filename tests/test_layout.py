"""Layout-equivalence suite (PR CI fast tier): ISSUE 6 acceptance contracts.

The post-build layout pass (core/layout.py, DESIGN.md §10) repacks the
adjacency to a fixed degree and renumbers vertices for locality; its whole
safety argument is the permutation contract — external callers must see
IDENTICAL results before and after `optimize()`.  Four contracts:

  * **bitwise equivalence** — `OptimizedIndex.search` returns bitwise-
    identical ids, dists AND n_expanded to the unoptimized search, on all
    three precision rungs (fp32/bf16/int8 + rescore), filtered and
    unfiltered, dense and hashed (cap ≥ N) visited sets, for both the
    "bfs" and "hub" orderings — and under ANY random permutation
    (hypothesis property);
  * **pack/unpack laws** — packing is a stable sentinel compaction that
    preserves distance-rank edge order; `unpack(pack(g, D), R)` equals
    `pack(g, R)` whenever no row exceeds degree D (hypothesis property);
  * **sharded parity** — `OptimizedIndex.distributed_search` matches the
    single-device optimized search bitwise across 1/2/4 shards, and the
    `ids_map` operand is part of the shard_map executable cache key (an
    unmapped compile can never serve a mapped call of identical shapes);
  * **pruning semantics** — detour pruning is opt-in, bounds the degree,
    only ever KEEPS original edges (never invents them), and holds a
    recall floor at half degree on the fast-tier corpus.

Runs in BOTH CI legs (REPRO_KERNEL_BACKEND=ref and =interpret): sizes are
kept small enough for the Python-stepped interpret kernels.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import grnnd, labels as L, layout as LY, recall
from repro.core import vecstore as VS
from repro.core.search import search
from repro.data import synthetic
from conftest import optional_hypothesis

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity


given, settings, st = optional_hypothesis()

K = 10
EF = 32
N = 260
NQ = 12
CFG = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)


@pytest.fixture(scope="module")
def case():
    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", N)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, NQ)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x, CFG)
    return x, q, pool


def _assert_same(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids),
                                  err_msg=f"{msg}/ids")
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists),
                                  err_msg=f"{msg}/dists")
    np.testing.assert_array_equal(np.asarray(a.n_expanded),
                                  np.asarray(b.n_expanded),
                                  err_msg=f"{msg}/n_expanded")


# ---------------------------------------------------------------------------
# packed adjacency: unit laws
# ---------------------------------------------------------------------------

def test_pack_is_stable_rank_preserving_compaction():
    g = np.array([[3, -1, 7, -1, 2],
                  [-1, -1, -1, -1, -1],
                  [1, 2, 3, 4, 5]], np.int32)
    assert LY.packed_degree(g) == 5
    p = LY.pack_adjacency(g)
    # interior holes squeezed out, rank order preserved, -1 tail pad
    np.testing.assert_array_equal(p, [[3, 7, 2, -1, -1],
                                      [-1, -1, -1, -1, -1],
                                      [1, 2, 3, 4, 5]])
    # explicit smaller degree truncates by rank; larger degree pads
    np.testing.assert_array_equal(LY.pack_adjacency(g, 2),
                                  [[3, 7], [-1, -1], [1, 2]])
    assert LY.pack_adjacency(g, 7).shape == (3, 7)


def test_unpack_roundtrip_fixed():
    g = np.array([[5, -1, 1], [-1, 2, -1]], np.int32)
    np.testing.assert_array_equal(
        LY.unpack_adjacency(LY.pack_adjacency(g, 2), 3),
        LY.pack_adjacency(g, 3))
    with pytest.raises(AssertionError):
        LY.unpack_adjacency(LY.pack_adjacency(g, 2), 1)  # r < d


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_pack_unpack_roundtrip_property(data):
    """For any pool whose rows all fit in degree D, packing to D and
    unpacking to the original width R is the canonical packed form at R —
    no edge is lost, duplicated, or reordered."""
    n = data.draw(st.integers(1, 12))
    r = data.draw(st.integers(1, 9))
    d = data.draw(st.integers(1, r))
    rows = data.draw(st.lists(
        st.lists(st.integers(0, n - 1), min_size=0, max_size=d,
                 unique=True),
        min_size=n, max_size=n))
    g = np.full((n, r), -1, np.int32)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    for i, edges in enumerate(rows):
        # scatter the ≤ d edges into random columns (holes anywhere)
        cols = rng.choice(r, size=len(edges), replace=False)
        g[i, np.sort(cols)] = edges
    np.testing.assert_array_equal(
        LY.unpack_adjacency(LY.pack_adjacency(g, d), r),
        LY.pack_adjacency(g, r))


def test_order_permutations_are_bijections(case):
    x, _, pool = case
    g = np.asarray(pool.ids)
    valid = np.ones(N, bool)
    valid[::7] = False
    for order in LY.ORDERS:
        for v in (None, valid):
            perm = LY.order_permutation(g, order, entry=3, valid=v)
            assert np.array_equal(np.sort(perm), np.arange(N)), order
    # identity really is the identity; bfs puts the entry first
    np.testing.assert_array_equal(
        LY.order_permutation(g, "identity"), np.arange(N))
    assert LY.order_permutation(g, "bfs", entry=17)[17] == 0


# ---------------------------------------------------------------------------
# bitwise equivalence: optimized == unoptimized, per precision rung
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ["bfs", "hub"])
@pytest.mark.parametrize("precision", VS.PRECISIONS)
def test_optimized_search_bitwise_equal(case, precision, order):
    """The acceptance core: renumbering + packing changes NOTHING the
    caller can observe — ids (in original numbering), dists, and the
    n_expanded trajectory are bitwise identical on every precision rung,
    with the int8 rung exercising the fp32 rescore tier through the
    permutation as well."""
    x, q, pool = case
    vs = x if precision == "fp32" else VS.encode(x, precision)
    rescore = None if precision == "fp32" else x
    base = search(vs, pool.ids, q, k=K, ef=EF, rescore=rescore)
    opt = LY.optimize(vs, pool, order=order, rescore=rescore)
    assert opt.order == order and not opt.pruned
    assert opt.degree == LY.packed_degree(pool.ids)
    _assert_same(base, opt.search(q, k=K, ef=EF), f"{precision}/{order}")


def test_optimized_search_filtered_bitwise_equal(case):
    """Filtered search: the label words permute with the vertices and the
    per-query predicate is row-independent, so the filtered result set is
    bitwise unchanged too."""
    x, q, pool = case
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(3), (N,), 0, 20), 20)
    fw = L.random_query_filters(jax.random.PRNGKey(4), NQ, 20, 0.25)
    base = search(x, pool.ids, q, k=K, ef=EF, labels=store, filter=fw)
    opt = LY.optimize(x, pool, order="bfs", labels=store)
    got = opt.search(q, k=K, ef=EF, filter=fw)
    _assert_same(base, got, "filtered")
    assert L.predicate_fraction(got.ids, fw, store.words) == 1.0


def test_optimized_search_filtered_int8_rescore_bitwise_equal(case):
    """The full stack at once: int8 traversal + fp32 rescore + filter +
    tombstones, through a hub renumbering."""
    x, q, pool = case
    vs = VS.encode(x, "int8")
    valid = jax.random.bernoulli(jax.random.PRNGKey(5), 0.85, (N,))
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(6), (N,), 0, 12), 12)
    fw = L.random_query_filters(jax.random.PRNGKey(7), NQ, 12, 0.3)
    base = search(vs, pool.ids, q, k=K, ef=EF, valid=valid, rescore=x,
                  labels=store, filter=fw)
    opt = LY.optimize(vs, pool, order="hub", valid=valid, rescore=x,
                      labels=store)
    _assert_same(base, opt.search(q, k=K, ef=EF, filter=fw), "full-stack")


@pytest.mark.parametrize("visited,cap", [("dense", None), ("hashed", 512)])
def test_optimized_search_visited_modes_bitwise_equal(case, visited, cap):
    """Dense visited is positional (trivially permutation-safe); the
    hashed table is bitwise-safe at cap ≥ N, where identity-mod probing
    is injective — the contract DESIGN.md §10 documents."""
    x, q, pool = case
    base = search(x, pool.ids, q, k=K, ef=EF, visited=visited,
                  visited_cap=cap)
    opt = LY.optimize(x, pool, order="bfs")
    _assert_same(base, opt.search(q, k=K, ef=EF, visited=visited,
                                  visited_cap=cap), visited)


_PROP = {}


def _prop_case():
    """Self-contained (no pytest fixture) corpus for the hypothesis
    property — hypothesis re-runs the test body per example and must not
    interact with fixture lifecycles."""
    if not _PROP:
        x = synthetic.make_preset(jax.random.PRNGKey(8), "tiny", 160)
        q = synthetic.queries_from(jax.random.PRNGKey(9), x, 8)
        pool = grnnd.build_graph(
            jax.random.PRNGKey(10), x,
            grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2, pairs_per_vertex=8))
        _PROP["case"] = (x, q, pool, search(x, pool.ids, q, k=5, ef=16))
    return _PROP["case"]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_search_invariant_under_any_permutation(seed):
    """The property behind the whole pass: not just the bfs/hub orders —
    ANY bijection on [0, N) leaves the search bitwise invariant once the
    inverse map is applied to the returned ids."""
    x, q, pool, base = _prop_case()
    perm = np.random.default_rng(seed).permutation(x.shape[0])
    opt = LY.optimize(x, pool, permutation=perm)
    assert opt.order == "custom"
    _assert_same(base, opt.search(q, k=5, ef=16), f"perm-seed{seed}")


def test_optimize_rejects_non_bijection(case):
    x, _, pool = case
    bad = np.zeros(N, np.int64)
    with pytest.raises(AssertionError):
        LY.optimize(x, pool, permutation=bad)


# ---------------------------------------------------------------------------
# detour pruning (opt-in; intentionally NOT bitwise)
# ---------------------------------------------------------------------------

def test_pruned_index_degree_subset_and_recall(case):
    x, q, pool = case
    d = LY.packed_degree(pool.ids)
    target = max(2, d // 2)
    opt = LY.optimize(x, pool, order="bfs", prune=True, degree=target)
    assert opt.pruned and opt.degree == target
    # pruning only ever KEEPS edges: every optimized row's ids, mapped
    # back to original numbering, are a subset of the original pool row
    g_opt = np.asarray(opt.graph_ids)
    inv = np.asarray(opt.inv)
    g_orig = np.asarray(pool.ids)
    for new in range(N):
        old = inv[new]
        kept = g_opt[new][g_opt[new] >= 0]
        assert set(inv[kept].tolist()) <= set(
            g_orig[old][g_orig[old] >= 0].tolist()), old
    gt = recall.brute_force_knn(x, q, K)
    rec = recall.recall_at_k(opt.search(q, k=K, ef=EF).ids, gt)
    assert rec >= 0.9, rec


def test_detour_counts_chain():
    """Hand-checkable 3-vertex chain 0–1–2: the two long edges (0→2 and
    2→0, both rank 1, d=4) are detourable through the middle vertex 1
    (both hops d=1); the middle vertex's own edges are not."""
    ids = np.array([[1, 2], [0, 2], [1, 0]], np.int32)
    dists = np.array([[1.0, 4.0], [1.0, 1.0], [1.0, 4.0]], np.float32)
    counts = LY.detour_counts(ids, dists)
    np.testing.assert_array_equal(counts, [[0, 1], [0, 0], [0, 1]])
    pruned = LY.prune_adjacency(ids, dists, 1)
    np.testing.assert_array_equal(pruned, [[1], [0], [1]])


# ---------------------------------------------------------------------------
# sharded parity: ids_map through distributed_search
# ---------------------------------------------------------------------------

def test_distributed_optimized_matches_and_keys_cache(case):
    """Single-shard mesh in-process: the optimized distributed search is
    bitwise-identical to the in-process optimized search, and `has_map`
    is part of the shard_map executable cache key — an unmapped compile
    of identical shapes is never reused for a mapped call."""
    from repro.core import distributed
    from repro.core.distributed import _sharded_search_fn
    x, q, pool = case
    mesh = jax.make_mesh((1,), ("lay",))
    opt = LY.optimize(x, pool, order="bfs")
    want = opt.search(q, k=K, ef=EF)
    _ = distributed.distributed_search(mesh, ("lay",), opt.x, opt.graph_ids,
                                       q, k=K, ef=EF, entry=opt.entry)
    before = _sharded_search_fn.cache_info().currsize
    got = opt.distributed_search(mesh, ("lay",), q, k=K, ef=EF)
    after = _sharded_search_fn.cache_info().currsize
    assert after == before + 1  # has_map keys the executable
    _assert_same(want, got, "dist-1shard")
    _assert_same(search(x, pool.ids, q, k=K, ef=EF), got, "dist-vs-base")


@pytest.mark.slow
def test_distributed_optimized_shard_count_invariance():
    """2/4-shard subprocess (forced host devices): the optimized
    distributed search stays bitwise-identical to BOTH the single-device
    optimized search and the unoptimized baseline, per precision rung —
    the ids_map shards as replicated state, so shard count is invisible."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax
        from repro.core import grnnd, layout as LY
        from repro.core import vecstore as VS
        from repro.core.search import search
        from repro.data import synthetic

        x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 300)
        q = synthetic.queries_from(jax.random.PRNGKey(1), x, 18)  # 18 % 4 != 0
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
        pool = grnnd.build_graph(jax.random.PRNGKey(2), x, cfg)

        out = {}
        for prec in VS.PRECISIONS:
            vs = x if prec == "fp32" else VS.encode(x, prec)
            rescore = None if prec == "fp32" else x
            base = search(vs, pool.ids, q, k=10, ef=32, rescore=rescore)
            opt = LY.optimize(vs, pool, order="bfs", rescore=rescore)
            single = opt.search(q, k=10, ef=32)
            for s in (1, 2, 4):
                m = jax.make_mesh((s,), ("data",),
                                  devices=jax.devices()[:s])
                got = opt.distributed_search(m, ("data",), q, k=10, ef=32)
                out[f"{prec}-shards{s}"] = {
                    "vs_single": (
                        np.array_equal(np.asarray(single.ids),
                                       np.asarray(got.ids))
                        and np.array_equal(np.asarray(single.dists),
                                           np.asarray(got.dists))),
                    "vs_base": (
                        np.array_equal(np.asarray(base.ids),
                                       np.asarray(got.ids))
                        and np.array_equal(np.asarray(base.dists),
                                           np.asarray(got.dists))),
                    "shape_ok": got.ids.shape == base.ids.shape,
                }
        print("RESULT" + json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT"):])
    for key, r in res.items():
        assert r["shape_ok"], key
        assert r["vs_single"], key
        assert r["vs_base"], key
