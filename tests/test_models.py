"""Model correctness: attention equivalences, SSD oracle, MoE dispatch,
prefill/decode cache consistency, per-arch smoke tests (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Full per-arch smoke sweep: the heaviest module (~70 s) — nightly tier.
pytestmark = pytest.mark.slow

from repro.configs import ALL_ARCHS, reduced
from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import transformer as T


def _text_cfg(**kw):
    base = dict(
        name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_head=8, d_ff=64, vocab=64)
    base.update(kw)
    return ArchConfig(**base)


def make_batch(key, cfg, b, s):
    if cfg.modality == "audio_tokens":
        return {"tokens": jax.random.randint(key, (b, s, cfg.n_codebooks),
                                             0, cfg.vocab)}
    if cfg.modality == "vision_text":
        k1, k2 = jax.random.split(key)
        return {
            "tokens": jax.random.randint(
                k1, (b, s - cfg.vision_tokens), 0, cfg.vocab),
            "patch_embeds": jax.random.normal(
                k2, (b, cfg.vision_tokens, cfg.vision_dim)),
        }
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class TestAttention:
    @pytest.mark.parametrize("window", [0, 16])
    @pytest.mark.parametrize("h,k", [(4, 4), (4, 2), (4, 1)])
    def test_blockwise_matches_full(self, window, h, k):
        cfg = _text_cfg(n_heads=h, n_kv_heads=k, window=window,
                        attn_softcap=20.0)
        key = jax.random.PRNGKey(0)
        b, s, dh = 2, 128, cfg.head_dim
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, dh))
        kk = jax.random.normal(ks[1], (b, s, k, dh))
        v = jax.random.normal(ks[2], (b, s, k, dh))
        pos = jnp.arange(s)
        want = A.full_attention(q, kk, v, cfg, pos, pos, window=window)
        got = A.blockwise_attention(q, kk, v, cfg, window=window,
                                    q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_decode_matches_full_last_position(self):
        cfg = _text_cfg()
        key = jax.random.PRNGKey(1)
        b, s, h, k, dh = 2, 32, 4, 2, 8
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, dh))
        kk = jax.random.normal(ks[1], (b, s, k, dh))
        v = jax.random.normal(ks[2], (b, s, k, dh))
        pos = jnp.arange(s)
        full = A.full_attention(q, kk, v, cfg, pos, pos)
        dec = A.decode_attention(q[:, -1:], kk, v, cfg,
                                 jnp.full((b,), s - 1))
        np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=1e-5,
                                   atol=1e-5)

    def test_local_mask_blocks_distant_positions(self):
        cfg = _text_cfg(window=4)
        b, s, h, dh = 1, 16, 4, 8
        key = jax.random.PRNGKey(2)
        q = jax.random.normal(key, (b, s, h, dh))
        k = jax.random.normal(key, (b, s, 2, dh))
        # v rows one-hot per position: output reveals attended positions
        v = jnp.zeros((b, s, 2, dh)).at[:, :, :, 0].set(
            jnp.arange(s, dtype=jnp.float32)[None, :, None])
        pos = jnp.arange(s)
        out = A.full_attention(q, k, v, cfg, pos, pos, window=4)
        # position 15 may only attend 12..15 => weighted mean in [12, 15]
        val = float(out[0, 15, 0, 0])
        assert 12.0 <= val <= 15.0


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

class TestSSD:
    @pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 16), (17, 8)])
    def test_chunked_matches_naive(self, s, chunk):
        key = jax.random.PRNGKey(3)
        b, nh, hd, st = 2, 3, 4, 5
        ks = jax.random.split(key, 4)
        xh = jax.random.normal(ks[0], (b, s, nh, hd))
        a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, nh)) + 1.0)
        bb = jax.random.normal(ks[2], (b, s, st))
        cc = jax.random.normal(ks[3], (b, s, st))
        h0 = jnp.zeros((b, nh, hd, st))
        y1, h1 = S.ssd_naive(xh, a, bb, cc, h0)
        y2, h2 = S._ssd_chunked(xh, a, bb, cc, h0, chunk)
        np.testing.assert_allclose(y2, y1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(h2, h1, rtol=1e-4, atol=1e-4)

    def test_nonzero_initial_state(self):
        key = jax.random.PRNGKey(4)
        b, s, nh, hd, st = 1, 16, 2, 4, 3
        ks = jax.random.split(key, 5)
        xh = jax.random.normal(ks[0], (b, s, nh, hd))
        a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, nh)))
        bb = jax.random.normal(ks[2], (b, s, st))
        cc = jax.random.normal(ks[3], (b, s, st))
        h0 = jax.random.normal(ks[4], (b, nh, hd, st))
        y1, h1 = S.ssd_naive(xh, a, bb, cc, h0)
        y2, h2 = S._ssd_chunked(xh, a, bb, cc, h0, 8)
        np.testing.assert_allclose(y2, y1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(h2, h1, rtol=1e-4, atol=1e-4)

    def test_ssm_block_prefill_decode_consistency(self):
        """Running T tokens chunked == prefill T-1 then decode 1."""
        cfg = reduced([a for a in ALL_ARCHS if a.name == "mamba2-130m"][0])
        key = jax.random.PRNGKey(5)
        params = S.init_ssm_params(key, cfg)
        b, s = 2, 17
        x = 0.1 * jax.random.normal(key, (b, s, cfg.d_model))
        full = S.ssm_block(params, cfg, x)
        out_prefix, cache = S.ssm_block(params, cfg, x[:, :-1],
                                        return_cache=True)
        out_last, _ = S.ssm_decode_block(params, cfg, x[:, -1:], cache)
        np.testing.assert_allclose(out_prefix, full[:, :-1], rtol=2e-3,
                                   atol=2e-3)
        np.testing.assert_allclose(out_last, full[:, -1:], rtol=2e-3,
                                   atol=2e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

class TestMoE:
    def _cfg(self, **kw):
        base = dict(n_experts=8, top_k=2, d_expert=16,
                    moe_capacity_factor=8.0)  # huge capacity => no drops
        base.update(kw)
        return _text_cfg(**base)

    def test_matches_dense_reference(self):
        """With no capacity drops, permute-MoE == explicit per-token loop."""
        cfg = self._cfg()
        key = jax.random.PRNGKey(6)
        params = M.init_moe_params(key, cfg)
        x = jax.random.normal(key, (2, 8, cfg.d_model))
        got, aux = M.moe_block(params, cfg, x)
        assert aux["moe_drop_frac"] == 0.0

        xt = x.reshape(-1, cfg.d_model)
        logits = xt @ params["router"]
        probs = jax.nn.softmax(logits, -1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / w.sum(-1, keepdims=True)
        want = np.zeros_like(xt)
        for t in range(xt.shape[0]):
            for j in range(cfg.top_k):
                e = int(idx[t, j])
                g = jax.nn.silu(xt[t] @ params["wi_gate"][e])
                u = xt[t] @ params["wi_up"][e]
                want[t] += float(w[t, j]) * np.asarray((g * u) @ params["wo"][e])
        np.testing.assert_allclose(
            got.reshape(-1, cfg.d_model), want, rtol=2e-3, atol=2e-3)

    def test_capacity_drops_tokens(self):
        cfg = self._cfg(moe_capacity_factor=0.1)
        key = jax.random.PRNGKey(7)
        params = M.init_moe_params(key, cfg)
        x = jax.random.normal(key, (4, 32, cfg.d_model))
        _, aux = M.moe_block(params, cfg, x)
        assert aux["moe_drop_frac"] > 0.0

    def test_shared_experts_always_active(self):
        cfg = self._cfg(n_shared_experts=1)
        key = jax.random.PRNGKey(8)
        params = M.init_moe_params(key, cfg)
        x = jax.random.normal(key, (2, 8, cfg.d_model))
        out_with, _ = M.moe_block(params, cfg, x)
        p2 = dict(params)
        p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
        out_without, _ = M.moe_block(p2, cfg, x)
        assert float(jnp.max(jnp.abs(out_with - out_without))) > 1e-4


# ---------------------------------------------------------------------------
# prefill/decode consistency through the full stack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_name", [
    "gemma2-2b", "deepseek-moe-16b", "mamba2-130m", "zamba2-7b",
    "musicgen-large",
])
def test_prefill_decode_matches_forward(arch_name):
    # huge MoE capacity: token drops differ between a (B*S)-token forward
    # and a B-token decode batch, which is true capacity semantics, not a
    # cache bug — eliminate drops to isolate cache correctness.
    cfg = reduced([a for a in ALL_ARCHS if a.name == arch_name][0],
                  moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(9)
    params = T.init_params(key, cfg)
    b, s = 2, 24
    batch = make_batch(key, cfg, b, s)
    full_logits, _ = T.forward(params, cfg, batch, act_dtype=jnp.float32,
                               remat=False)

    if cfg.modality == "audio_tokens":
        prompt = {"tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1]
    else:
        prompt = dict(batch)
        prompt["tokens"] = batch["tokens"][:, :-1]
        last_tok = batch["tokens"][:, -1]
    _, caches, plen = T.prefill(params, cfg, prompt, s_max=s + 2,
                                act_dtype=jnp.float32)
    pos = jnp.full((b,), plen, jnp.int32)
    dec_logits, _ = T.decode_step(params, cfg, caches, last_tok, pos,
                                  act_dtype=jnp.float32)
    np.testing.assert_allclose(
        dec_logits, full_logits[:, -1], rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# per-arch smoke tests (deliverable f): one fwd/train step, shapes, no NaNs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ALL_ARCHS, ids=lambda a: a.name)
def test_arch_smoke(arch):
    cfg = reduced(arch)
    key = jax.random.PRNGKey(10)
    params = T.init_params(key, cfg)
    b, s = 2, 32
    batch = make_batch(key, cfg, b, s)
    logits, aux = T.forward(params, cfg, batch)
    if cfg.modality == "audio_tokens":
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    # one gradient step on the CE loss
    from repro.train.train_step import loss_fn
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch)[0])(params)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)


def test_param_counts_match_targets():
    """Full configs hit their published parameter counts (±15%)."""
    targets = {
        "gemma2-2b": 2.6e9,        # incl. 590M embeddings
        "h2o-danube-1.8b": 1.8e9,
        "gemma3-27b": 27e9,
        "gemma3-1b": 1.0e9,
        "deepseek-moe-16b": 16.4e9,
        "qwen3-moe-235b-a22b": 235e9,
        "musicgen-large": 3.3e9,
        "mamba2-130m": 130e6,
        # the assignment's dims (81L/3584/14336, ssm_state=64) yield ~5.6B;
        # the released model adds LoRA adapters + dual shared blocks we
        # don't model — target the assignment-faithful count.
        "zamba2-7b": 5.6e9,
        "internvl2-2b": 1.9e9,     # LM backbone share
    }
    for arch in ALL_ARCHS:
        got = arch.param_count()
        want = targets[arch.name]
        assert 0.8 * want < got < 1.35 * want, (arch.name, got, want)
