"""Expert-parallel MoE (shard_map) must match the dense reference path.

Runs in a subprocess with 8 forced host devices (mesh must exist before
shard_map traces).  This is the §Perf iteration A1 correctness lock.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Subprocess with 8 forced host devices (~12 s) — nightly tier.
pytestmark = pytest.mark.slow

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ArchConfig
    from repro.models import moe as M
    from repro.distributed import hints as H

    out = {}
    for ncfg, (e, k, shared) in {
        "plain": (8, 2, 0),
        "shared": (8, 2, 1),
        "finegrained": (16, 4, 2),
    }.items():
        cfg = ArchConfig(
            name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
            n_kv_heads=2, d_head=8, d_ff=64, vocab=64,
            n_experts=e, top_k=k, d_expert=16, n_shared_experts=shared,
            moe_capacity_factor=16.0)
        params = M.init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        dense, aux_d = M.moe_block(params, cfg, x)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with H.use_hints(mesh), mesh:
            ep, aux_e = jax.jit(
                lambda p, v: M.moe_block(p, cfg, v))(params, x)
        out[ncfg] = {
            "err": float(jnp.max(jnp.abs(dense - ep))),
            "scale": float(jnp.max(jnp.abs(dense))),
            "drop_dense": float(aux_d["moe_drop_frac"]),
            "drop_ep": float(aux_e["moe_drop_frac"]),
        }
        # gradient parity through the EP path
        def loss(p, path):
            with H.use_hints(mesh) if path == "ep" else _null():
                y, _ = M.moe_block(p, cfg, x)
            return jnp.sum(y ** 2)
        import contextlib
        def _null():
            return contextlib.nullcontext()
        g_d = jax.grad(lambda p: jnp.sum(M.moe_block(p, cfg, x)[0] ** 2))(
            params)
        with H.use_hints(mesh), mesh:
            g_e = jax.jit(jax.grad(
                lambda p: jnp.sum(M.moe_block(p, cfg, x)[0] ** 2)))(params)
        gerr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(g_d),
                                   jax.tree.leaves(g_e)))
        out[ncfg]["grad_err"] = gerr
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def ep_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("variant", ["plain", "shared", "finegrained"])
def test_ep_matches_dense(ep_results, variant):
    r = ep_results[variant]
    assert r["err"] < 1e-5 * max(r["scale"], 1.0), r


@pytest.mark.parametrize("variant", ["plain", "shared", "finegrained"])
def test_ep_gradients_match_dense(ep_results, variant):
    assert ep_results[variant]["grad_err"] < 1e-3, ep_results[variant]  # fp reduction-order tolerance


def test_no_drops_at_high_capacity(ep_results):
    for r in ep_results.values():
        assert r["drop_dense"] == 0.0
        assert r["drop_ep"] == 0.0
