"""Precision-ladder test tier (ISSUE 4, DESIGN.md §8).

Four contracts:

  * the bf16 and int8 variants of every row-gather kernel (rng_round,
    search_expand, gather_l2) match their ref.py oracles BITWISE in
    interpret mode — the fused in-kernel dequant is the same elementwise
    formula as `ref.dequant_rows`, so quantization adds no parity slack;
  * the pairwise kernel's quantized variants match at its established
    tolerance (its D-slab accumulation makes the reduction tree differ
    from the whole-row oracle by design — same convention as the fp32
    suite in tests/test_kernels.py);
  * the int8 quantizer obeys its analytic bounds (hypothesis property
    tier): round-trip error |x - dq(q(x))| <= scale/2 per dimension, and
    monotone 1-D distance ordering (quantization is a monotone map, so
    collinear same-side orderings survive);
  * a graph BUILT through the ref backend and one built through the
    interpret backend produce identical pool ids at every precision —
    the cross-backend determinism the dispatch layer promises and the
    pre-ladder suite never checked.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import grnnd, vecstore as VS
from repro.core.search import _table_insert, search
from repro.data import synthetic
from repro.kernels import ops, ref
from repro.kernels.gather_l2 import gather_sqdist_pallas
from repro.kernels.pairwise_l2 import pairwise_sqdist_pallas
from repro.kernels.rng_round import rng_round_pallas
from repro.kernels.search_expand import search_expand_pallas

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity


PRECS = ("bf16", "int8")


def _store(seed: int, n: int, d: int, precision: str) -> VS.VectorStore:
    x = synthetic.vector_dataset(jax.random.PRNGKey(seed), n, d,
                                 n_clusters=max(2, n // 16))
    return VS.encode(x, precision)


# ---------------------------------------------------------------------------
# kernel/oracle parity per precision (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", PRECS)
@pytest.mark.parametrize("n,d,c,r,p", [(64, 12, 10, 8, 6), (50, 33, 7, 5, 9)])
def test_rng_round_parity(precision, n, d, c, r, p):
    st_ = _store(11, n, d, precision)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(1), 4)
    ids = jax.random.randint(k1, (c, r), -1, n)
    lut = jnp.abs(jax.random.normal(k2, (n,)))
    dists = jnp.where(ids >= 0, lut[jnp.clip(ids, 0)], jnp.inf)
    si = jax.random.randint(k3, (c, p), 0, r)
    sj = jax.random.randint(k4, (c, p), 0, r)
    got = rng_round_pallas(st_.data, ids, dists, si, sj,
                           st_.scale, st_.offset, interpret=True)
    want = jax.jit(ref.rng_round_ref)(st_.data, ids, dists, si, sj,
                                      st_.scale, st_.offset)
    for name, g, w in zip(("dst", "src", "dij", "kill"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{precision}/{name}")


@pytest.mark.parametrize("precision", PRECS)
@pytest.mark.parametrize("qn,r,n,d,h", [(8, 10, 64, 12, 32), (5, 7, 50, 33, 16)])
def test_search_expand_parity(precision, qn, r, n, d, h):
    st_ = _store(13, n, d, precision)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (qn, d))
    nbrs = jax.random.randint(k2, (qn, r), -1, n)
    tab = _table_insert(jnp.full((qn, h), -1, jnp.int32), jnp.where(
        jax.random.bernoulli(k3, 0.5, (qn, r)), nbrs, -1))
    got = search_expand_pallas(st_.data, q, nbrs, tab, None,
                               st_.scale, st_.offset, interpret=True)
    want = jax.jit(ref.search_expand_ref)(st_.data, q, nbrs, tab, None,
                                          st_.scale, st_.offset)
    for name, g, w in zip(("ids", "dists", "fresh"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{precision}/{name}")


@pytest.mark.parametrize("precision", PRECS)
@pytest.mark.parametrize("n,d,m", [(64, 12, 40), (30, 65, 17)])
def test_gather_l2_parity(precision, n, d, m):
    st_ = _store(17, n, d, precision)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    ni = jax.random.randint(k1, (m,), 0, n)
    nj = jax.random.randint(k2, (m,), 0, n)
    got = gather_sqdist_pallas(st_.data, ni, nj, st_.scale, st_.offset,
                               interpret=True)
    want = jax.jit(ref.gather_sqdist_ref)(st_.data, ni, nj,
                                          st_.scale, st_.offset)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                  err_msg=precision)


@pytest.mark.parametrize("precision", PRECS)
@pytest.mark.parametrize("m,n,d", [(17, 33, 12), (64, 64, 128)])
def test_pairwise_parity(precision, m, n, d):
    """Quantized-side pairwise vs oracle, at the suite's established
    tolerance (tests/test_kernels.py): both sides see bitwise-identical
    dequantized values, only the D-slab accumulation order differs."""
    st_ = _store(19, n, d, precision)
    q = jax.random.normal(jax.random.PRNGKey(4), (m, d))
    got = pairwise_sqdist_pallas(q, st_.data, None, None,
                                 st_.scale, st_.offset,
                                 bm=32, bn=32, bk=128, interpret=True)
    want = ref.pairwise_sqdist_ref(q, st_.data,
                                   y_scale=st_.scale, y_offset=st_.offset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5 * d, err_msg=precision)


def test_ops_dispatch_accepts_stores():
    """Every ops entry point takes a VectorStore on both backends and the
    two backends agree (bitwise for the row-gather ops)."""
    st_ = _store(23, 48, 16, "int8")
    q = jax.random.normal(jax.random.PRNGKey(5), (6, 16))
    ids = jax.random.randint(jax.random.PRNGKey(6), (6, 8), -1, 48)
    lut = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (48,)))
    dists = jnp.where(ids >= 0, lut[jnp.clip(ids, 0)], jnp.inf)
    si = jax.random.randint(jax.random.PRNGKey(8), (6, 4), 0, 8)
    sj = jax.random.randint(jax.random.PRNGKey(9), (6, 4), 0, 8)
    tab = jnp.full((6, 16), -1, jnp.int32)
    ni = jax.random.randint(jax.random.PRNGKey(10), (12,), 0, 48)
    nj = jax.random.randint(jax.random.PRNGKey(11), (12,), 0, 48)

    outs = {}
    for b in ("ref", "interpret"):
        with ops.backend(b):
            # one jit per op with operands passed as ARGUMENTS — the
            # library's calling convention and the parity contract's
            # common-jit-context requirement (closure-captured operands
            # would let XLA constant-fold the oracle's dequant with a
            # different evaluator); per-iteration lambdas keep the
            # backend traces separate
            outs[b] = (
                jax.jit(lambda *a: ops.pairwise_sqdist(*a))(q, st_),
                jax.jit(lambda *a: ops.rng_propagation_round(*a))(
                    st_, ids, dists, si, sj),
                jax.jit(lambda *a: ops.search_expand(*a))(st_, q, ids, tab),
                jax.jit(lambda *a: ops.gather_sqdist(*a))(st_, ni, nj),
            )
    np.testing.assert_allclose(np.asarray(outs["ref"][0]),
                               np.asarray(outs["interpret"][0]),
                               rtol=1e-5, atol=1e-4)
    for g, w in zip(outs["interpret"][1], outs["ref"][1]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    for g, w in zip(outs["interpret"][2], outs["ref"][2]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(outs["interpret"][3]),
                                  np.asarray(outs["ref"][3]))


# ---------------------------------------------------------------------------
# quantizer properties
# ---------------------------------------------------------------------------

def test_store_layout_and_bytes():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 24))
    s32 = VS.encode(x, "fp32")
    s16 = VS.encode(x, "bf16")
    s8 = VS.encode(x, "int8")
    assert s32.data.dtype == jnp.float32 and s32.scale is None
    assert s16.data.dtype == jnp.bfloat16 and s16.scale is None
    assert s8.data.dtype == jnp.int8
    assert s8.scale.shape == (24,) and s8.offset.shape == (24,)
    # 1 byte/dim + per-dim scale/offset held once for the whole store
    assert s8.bytes_per_vector() == 24.0
    assert s32.bytes_per_vector() == 4 * 24.0
    assert s16.bytes_per_vector() == 2 * 24.0
    assert s32.bytes_per_vector() / s16.bytes_per_vector() >= 2.0
    assert s32.bytes_per_vector() / s8.bytes_per_vector() >= 4.0
    assert s8.precision == "int8" and s16.precision == "bf16"


def test_quantizer_constant_dimension_exact():
    x = jnp.concatenate([jnp.full((8, 3), 2.5),
                         jax.random.normal(jax.random.PRNGKey(1), (8, 2))],
                        axis=1)
    st_ = VS.quantize_int8(x)
    np.testing.assert_allclose(np.asarray(st_.dequant()[:, :3]), 2.5)


def test_frozen_params_insert_roundtrip():
    """with_rows quantizes with the FROZEN scale/offset; in-range rows obey
    the same error bound, out-of-range rows clip to the range edge."""
    x = jax.random.normal(jax.random.PRNGKey(2), (40, 8))
    st_ = VS.quantize_int8(x)
    new = x[:4] * 0.5  # strictly in range
    st2 = st_.with_rows(jnp.arange(4), new)
    err = np.abs(np.asarray(new) - np.asarray(st2.take(jnp.arange(4))))
    assert (err <= np.asarray(st_.scale)[None, :] / 2 + 1e-6).all()
    far = jnp.full((1, 8), 1e6)
    st3 = st_.with_rows(jnp.array([0]), far)
    assert int(jnp.max(jnp.abs(st3.data[0].astype(jnp.int32)))) <= 127


@settings(deadline=None, max_examples=40)
@given(n=st.integers(2, 40), d=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_quantizer_roundtrip_bound(n, d, seed):
    """|x - dq(q(x))| <= scale/2 per dim, for the corpus the params were
    fit on (every value in [min, max], so no clipping)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 10.0
    st_ = VS.quantize_int8(x)
    err = np.abs(np.asarray(x) - np.asarray(st_.dequant()))
    bound = np.asarray(st_.scale)[None, :] / 2
    assert (err <= bound * (1 + 1e-5) + 1e-7).all(), (err.max(), bound.max())


@settings(deadline=None, max_examples=40)
@given(n=st.integers(0, 1), d=st.integers(1, 32),
       prec=st.sampled_from(tuple(p for p in VS.PRECISIONS if p != "fp32")),
       seed=st.integers(0, 2**31 - 1))
def test_quantizer_edge_corpora_well_defined(n, d, prec, seed):
    """The empty/degenerate-corpus contract (ISSUE 9 satellite): encoding
    an N ∈ {0, 1} corpus must not crash on the empty axis-0 reduction —
    N=0 freezes the identity params (scale 1, offset 0) so a later
    `with_rows` insert quantizes through a well-defined map; N=1 has zero
    range per dim and round-trips its one row exactly (the constant-
    dimension guard, here for EVERY dim at once)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 10.0
    st_ = VS.encode(x, prec)
    assert st_.data.shape == (n, d)
    if prec == "int8":  # bf16 is affine-free: scale/offset stay None
        assert np.isfinite(np.asarray(st_.scale)).all()
        assert np.isfinite(np.asarray(st_.offset)).all()
    dq = np.asarray(st_.dequant(), np.float32)
    assert dq.shape == (n, d) and np.isfinite(dq).all()
    if n == 1 and prec == "int8":
        np.testing.assert_allclose(dq, np.asarray(x), atol=1e-5)
    if n == 0 and prec == "int8":
        np.testing.assert_array_equal(np.asarray(st_.scale), 1.0)
        np.testing.assert_array_equal(np.asarray(st_.offset), 0.0)
        # the frozen identity map still admits inserts
        grown = st_._replace(data=jnp.zeros((4, d), st_.data.dtype))
        grown = grown.with_rows(jnp.arange(2),
                                jnp.linspace(-1, 1, 2 * d).reshape(2, d))
        assert np.isfinite(np.asarray(grown.dequant())).all()


@settings(deadline=None, max_examples=40)
@given(n=st.integers(3, 50), seed=st.integers(0, 2**31 - 1))
def test_quantizer_monotone_1d(n, seed):
    """Quantization is monotone: sorted 1-D inputs stay sorted after the
    round-trip, so distances measured from the minimum point are
    non-decreasing in the original order (weak ordering preservation)."""
    x = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed), (n,))).reshape(
        n, 1)
    dq = np.asarray(VS.quantize_int8(x).dequant())[:, 0]
    assert (np.diff(dq) >= 0).all()
    d0 = np.abs(dq - dq[0])
    assert (np.diff(d0) >= 0).all()


# ---------------------------------------------------------------------------
# cross-backend build determinism (the dispatch-drift guard)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def det_dataset():
    return synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 192)


@pytest.mark.parametrize("precision", ("fp32", "bf16", "int8"))
def test_build_determinism_ref_vs_interpret(det_dataset, precision):
    """The same build through REPRO_KERNEL_BACKEND=ref and through the
    interpret-mode Pallas kernels must produce IDENTICAL pool ids at every
    precision — guards the ops dispatch layer against silent drift between
    the oracle and kernel paths (the suite previously only checked this
    for the fp32 search)."""
    x = det_dataset
    xt = x if precision == "fp32" else VS.encode(x, precision)
    cfg = grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2, pairs_per_vertex=8)
    pools = {}
    for b in ("ref", "interpret"):
        with ops.backend(b):
            pools[b] = grnnd.build_graph(jax.random.PRNGKey(7), xt, cfg)
    np.testing.assert_array_equal(np.asarray(pools["ref"].ids),
                                  np.asarray(pools["interpret"].ids),
                                  err_msg=precision)


def test_dynamic_index_rebases_pool_into_traversal_space(det_dataset):
    """Wrapping an fp32-BUILT pool at int8 precision must re-base every
    stored pool distance into the traversal space — d(x̂_i, x̂_j), the
    values later RNG kills and merges compare against (§8.3) — not keep
    the fp32-space build distances."""
    x = det_dataset
    cfg = grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2, pairs_per_vertex=8)
    pool = grnnd.build_graph(jax.random.PRNGKey(7), x, cfg)  # fp32 build
    from repro.core.dynamic import DynamicConfig, DynamicIndex
    idx = DynamicIndex(x, pool, DynamicConfig(precision="int8"))
    n = x.shape[0]
    ids = np.asarray(idx.pool.ids[:n])
    dists = np.asarray(idx.pool.dists[:n])
    xq = np.asarray(idx.store.dequant()[:n])
    for i in range(0, n, 37):
        for slot, v in enumerate(ids[i]):
            if v < 0:
                assert np.isinf(dists[i, slot])
                continue
            want = float(((xq[i] - xq[v]) ** 2).sum())
            np.testing.assert_allclose(dists[i, slot], want, rtol=1e-5,
                                       atol=1e-6)
        dv = dists[i][ids[i] >= 0]
        assert (np.diff(dv) >= -1e-7).all()  # re-sorted pool invariant


# ---------------------------------------------------------------------------
# rescoring semantics
# ---------------------------------------------------------------------------

def test_rescore_returns_exact_fp32_distances(det_dataset):
    """After the rescoring pass every returned (id, dist) pair is the
    EXACT fp32 distance, and the fp32 path is unchanged by rescore=None."""
    x = det_dataset
    st_ = VS.encode(x, "int8")
    cfg = grnnd.GRNNDConfig(s=6, r=8, t1=2, t2=2, pairs_per_vertex=8)
    pool = grnnd.build_graph(jax.random.PRNGKey(7), st_, cfg)
    q = synthetic.queries_from(jax.random.PRNGKey(8), x, 12)
    res = search(st_, pool.ids, q, k=5, ef=16, rescore=x)
    r_ids, r_d = np.asarray(res.ids), np.asarray(res.dists)
    xs, qs = np.asarray(x), np.asarray(q)
    for qi in range(12):
        for slot, v in enumerate(r_ids[qi]):
            if v < 0:
                continue
            want = float(((qs[qi] - xs[v]) ** 2).sum())
            np.testing.assert_allclose(r_d[qi, slot], want, rtol=1e-5,
                                       atol=1e-6)
        dv = r_d[qi][r_ids[qi] >= 0]
        assert (np.diff(dv) >= -1e-7).all()  # re-sorted by exact distance
