"""Hypothesis property tests on system invariants (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import grnnd, pools
from repro.core.search import search
from repro.data import synthetic
from repro.kernels import ops

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity



@settings(deadline=None, max_examples=10)
@given(
    m=st.integers(1, 200),
    n=st.integers(2, 64),
    cap=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_group_requests_invariants(m, n, cap, seed):
    """Staging is always: in-range ids, per-row unique, ascending dists,
    self-inserts dropped, at most cap entries."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    req = pools.Requests(
        dst=jax.random.randint(k1, (m,), -1, n),
        src=jax.random.randint(k2, (m,), 0, n),
        dist=jnp.abs(jax.random.normal(k3, (m,))),
    )
    ids, dists = pools.group_requests(req, n, cap)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert ids.shape == (n, cap)
    for row in range(n):
        valid = ids[row][ids[row] >= 0]
        assert len(valid) == len(set(valid.tolist()))          # unique
        assert row not in valid                                 # no self
        dv = dists[row][ids[row] >= 0]
        assert np.all(np.diff(dv) >= -1e-7)                     # ascending
        assert np.all(valid < n)


@settings(deadline=None, max_examples=6)
@given(
    n=st.sampled_from([64, 128]),
    d=st.sampled_from([4, 16]),
    seed=st.integers(0, 1000),
)
def test_search_returns_true_distances(n, d, seed):
    """Every (id, dist) the search returns must satisfy
    dist == ||q - x[id]||^2 — no stale or fabricated entries."""
    key = jax.random.PRNGKey(seed)
    x = synthetic.vector_dataset(key, n, d, n_clusters=4)
    cfg = grnnd.GRNNDConfig(s=8, r=12, t1=2, t2=2, pairs_per_vertex=8)
    pool = grnnd.build_graph(jax.random.fold_in(key, 1), x, cfg)
    q = synthetic.queries_from(jax.random.fold_in(key, 2), x, 8)
    res = search(x, pool.ids, q, k=5, ef=16)
    ids, dists = np.asarray(res.ids), np.asarray(res.dists)
    xs = np.asarray(x)
    qs = np.asarray(q)
    for qi in range(qs.shape[0]):
        for slot in range(5):
            if ids[qi, slot] < 0:
                continue
            want = float(((qs[qi] - xs[ids[qi, slot]]) ** 2).sum())
            np.testing.assert_allclose(dists[qi, slot], want, rtol=1e-4,
                                       atol=1e-5)


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 1000), rho=st.floats(0.1, 1.0))
def test_reverse_edges_preserve_invariants(seed, rho):
    key = jax.random.PRNGKey(seed)
    x = synthetic.vector_dataset(key, 96, 8, n_clusters=4)
    cfg = grnnd.GRNNDConfig(s=8, r=12, t1=1, t2=1, rho=rho,
                            pairs_per_vertex=8)
    p = pools.init_random(jax.random.fold_in(key, 1), x, 8, 12)
    p2 = grnnd.reverse_edge_round(p, cfg)
    ids = np.asarray(p2.ids)
    rows = np.arange(96)[:, None]
    assert not np.any(ids == rows)
    for v in range(96):
        valid = ids[v][ids[v] >= 0]
        assert len(valid) == len(set(valid.tolist()))


@settings(deadline=None, max_examples=20)
@given(
    b=st.integers(1, 8),
    w=st.integers(1, 40),
    r=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_topr_merge_output_invariants(b, w, r, seed):
    """topr_merge output is sorted ascending, deduplicated, and packed:
    no -1 slot ever precedes a valid id (the beam merge in core/search.py
    relies on all three)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    ids = np.asarray(jax.random.randint(k1, (b, w), -1, 16))
    lut = np.asarray(jnp.abs(jax.random.normal(k2, (16,))))
    dists = np.where(ids >= 0, lut[np.clip(ids, 0, None)], np.inf)

    oi, od = ops.topr_merge(jnp.asarray(ids), jnp.asarray(dists), r)
    oi, od = np.asarray(oi), np.asarray(od)
    assert oi.shape == (b, r)
    for row in range(b):
        valid_mask = oi[row] >= 0
        valid = oi[row][valid_mask]
        assert len(valid) == len(set(valid.tolist()))           # dedup
        dv = od[row][valid_mask]
        assert np.all(np.diff(dv) >= -1e-7)                     # sorted
        assert np.all(np.isfinite(dv))
        # packed: once a -1 appears, every later slot is -1
        if not np.all(valid_mask):
            first_empty = int(np.argmin(valid_mask))
            assert not np.any(valid_mask[first_empty:])
        assert np.all(np.isinf(od[row][~valid_mask]))


@settings(deadline=None, max_examples=15)
@given(
    n=st.integers(2, 48),
    p=st.integers(1, 12),
    cap=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_stage_request_matrix_cap_and_parity(n, p, cap, seed):
    """The (N, P) fused-round staging respects the per-destination cap and
    is exactly group_requests on the row-major flattened matrices."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    dst = jax.random.randint(k1, (n, p), -1, n)
    src = jax.random.randint(k2, (n, p), 0, n)
    dist = jnp.abs(jax.random.normal(k3, (n, p)))

    ids, dists = pools.stage_request_matrix(dst, src, dist, n, cap)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert ids.shape == (n, cap)
    for row in range(n):
        valid = ids[row][ids[row] >= 0]
        assert len(valid) <= cap                                # cap held
        assert len(valid) == len(set(valid.tolist()))           # unique
        assert row not in valid                                 # no self
    flat = pools.Requests(dst=dst.reshape(-1), src=src.reshape(-1),
                          dist=dist.reshape(-1))
    ids2, dists2 = pools.group_requests(flat, n, cap)
    np.testing.assert_array_equal(ids, np.asarray(ids2))
    np.testing.assert_array_equal(dists, np.asarray(dists2))


def test_merge_idempotent():
    """Merging a pool with itself must be the identity."""
    x = synthetic.vector_dataset(jax.random.PRNGKey(0), 64, 8)
    p = pools.init_random(jax.random.PRNGKey(1), x, 8, 12)
    p2 = pools.merge_into(p, p.ids, p.dists)
    np.testing.assert_array_equal(np.asarray(p.ids), np.asarray(p2.ids))
