"""End-to-end recall regression suite (PR CI fast tier).

Build + search on seeded synthetic data against `brute_force_knn` ground
truth, with fixed recall@10 floors per construction order and visited-set
representation — so future kernel/search changes cannot silently degrade
graph *or* traversal quality.  Thresholds sit ~0.04 under the currently
measured values (disordered 0.90, ascending 0.96 on this config/seed) to
absorb benign PRNG/jax-version drift while still catching real
regressions.
"""
import jax
import pytest

from repro.core import grnnd, recall
from repro.core.search import search
from repro.data import synthetic

EF = 48
K = 10


@pytest.fixture(scope="module")
def dataset():
    x = synthetic.make_preset(jax.random.PRNGKey(0), "sift-like", 1200)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 128)
    gt = recall.brute_force_knn(x, q, K)
    return x, q, gt


@pytest.fixture(scope="module")
def graphs(dataset):
    x, _, _ = dataset
    out = {}
    for order in ("disordered", "ascending"):
        cfg = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16,
                                order=order)
        out[order] = grnnd.build_graph(jax.random.PRNGKey(2), x, cfg)
    return out


@pytest.mark.parametrize("order,floor", [
    ("disordered", 0.86),
    ("ascending", 0.92),
])
@pytest.mark.parametrize("visited", ["dense", "hashed"])
def test_recall_regression(dataset, graphs, order, floor, visited):
    x, q, gt = dataset
    res = search(x, graphs[order].ids, q, k=K, ef=EF, visited=visited)
    rec = recall.recall_at_k(res.ids, gt)
    assert rec >= floor, (order, visited, rec)


def test_hashed_matches_dense_recall(dataset, graphs):
    """Acceptance bound: the hashed visited set (default cap) may not cost
    more than 0.01 recall vs the dense baseline at equal ef."""
    x, q, gt = dataset
    ids = graphs["disordered"].ids
    r_dense = recall.recall_at_k(
        search(x, ids, q, k=K, ef=EF, visited="dense").ids, gt)
    r_hashed = recall.recall_at_k(
        search(x, ids, q, k=K, ef=EF, visited="hashed").ids, gt)
    assert r_hashed >= r_dense - 0.01, (r_dense, r_hashed)
