"""End-to-end recall regression suite (PR CI fast tier).

Build + search on seeded synthetic data against `brute_force_knn` ground
truth, with fixed recall@10 floors per construction order and visited-set
representation — so future kernel/search changes cannot silently degrade
graph *or* traversal quality.  Thresholds sit ~0.04 under the currently
measured values (disordered 0.90, ascending 0.96 on this config/seed) to
absorb benign PRNG/jax-version drift while still catching real
regressions.

The precision-ladder floors (ISSUE 4) pin the quantized rungs to the fp32
baseline MEASURED ON THE SAME SEEDS rather than to absolute values:
int8 + fp32 rescoring within 1 recall point, bf16 within 0.5 — the
DESIGN.md §8 acceptance bounds.
"""
import jax
import pytest

from repro.core import grnnd, recall, vecstore
from repro.core.search import search
from repro.data import synthetic

EF = 48
K = 10

# the single regression build config — the precision_runs fixture derives
# its quantized builds from the SAME object, so the fp32 baseline and the
# quantized rungs can never drift apart under a future re-tune
BUILD_CFG = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16,
                              order="disordered")


@pytest.fixture(scope="module")
def dataset():
    x = synthetic.make_preset(jax.random.PRNGKey(0), "sift-like", 1200)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 128)
    gt = recall.brute_force_knn(x, q, K)
    return x, q, gt


@pytest.fixture(scope="module")
def graphs(dataset):
    x, _, _ = dataset
    out = {}
    for order in ("disordered", "ascending"):
        cfg = BUILD_CFG._replace(order=order)
        out[order] = grnnd.build_graph(jax.random.PRNGKey(2), x, cfg)
    return out


@pytest.mark.parametrize("order,floor", [
    ("disordered", 0.86),
    ("ascending", 0.92),
])
@pytest.mark.parametrize("visited", ["dense", "hashed"])
def test_recall_regression(dataset, graphs, order, floor, visited):
    x, q, gt = dataset
    res = search(x, graphs[order].ids, q, k=K, ef=EF, visited=visited)
    rec = recall.recall_at_k(res.ids, gt)
    assert rec >= floor, (order, visited, rec)


def test_hashed_matches_dense_recall(dataset, graphs):
    """Acceptance bound: the hashed visited set (default cap) may not cost
    more than 0.01 recall vs the dense baseline at equal ef."""
    x, q, gt = dataset
    ids = graphs["disordered"].ids
    r_dense = recall.recall_at_k(
        search(x, ids, q, k=K, ef=EF, visited="dense").ids, gt)
    r_hashed = recall.recall_at_k(
        search(x, ids, q, k=K, ef=EF, visited="hashed").ids, gt)
    assert r_hashed >= r_dense - 0.01, (r_dense, r_hashed)


# ---------------------------------------------------------------------------
# precision-ladder regression floors (ISSUE 4 acceptance bounds)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def precision_runs(dataset, graphs):
    """Build + search the same seeded pipeline at every precision rung.

    The quantized graphs are BUILT on their stores (every build distance
    in storage-precision space), searched through the same unified path;
    int8/bf16 additionally rescore against the fp32 tier.  Returns
    recall@10 per (precision, rescored) cell plus the fp32 baseline.
    """
    x, q, gt = dataset
    out = {"fp32": recall.recall_at_k(
        search(x, graphs["disordered"].ids, q, k=K, ef=EF).ids, gt)}
    for prec in ("bf16", "int8"):
        store = vecstore.encode(x, prec)
        pool = grnnd.build_graph(jax.random.PRNGKey(2), store, BUILD_CFG)
        out[prec] = recall.recall_at_k(
            search(store, pool.ids, q, k=K, ef=EF).ids, gt)
        out[prec + "+rescore"] = recall.recall_at_k(
            search(store, pool.ids, q, k=K, ef=EF, rescore=x).ids, gt)
    return out


def test_int8_rescored_within_one_point_of_fp32(precision_runs):
    """The ISSUE 4 acceptance bound: int8 traversal + fp32 rescoring stays
    within 1 recall point of the fp32 pipeline on the same seeds."""
    r = precision_runs
    assert r["int8+rescore"] >= r["fp32"] - 0.01, r


def test_bf16_within_half_point_of_fp32(precision_runs):
    """bf16 storage (no rescoring) within 0.5 recall points of fp32."""
    r = precision_runs
    assert r["bf16"] >= r["fp32"] - 0.005, r


def test_rescoring_never_hurts(precision_runs):
    """Re-ranking the same candidate set by exact distances can only
    improve (or preserve) recall@k — a structural property, not a seed-
    dependent one."""
    r = precision_runs
    assert r["int8+rescore"] >= r["int8"] - 1e-9, r
    assert r["bf16+rescore"] >= r["bf16"] - 1e-9, r
