"""Fused propagation-round kernel (interpret mode) vs the ref.py oracle.

The contract is BITWISE parity under a common jit context: the slot-pair
samples are drawn outside the kernel, and the kernel's distance math
follows the same subtract-square-reduce order as the oracle, so kill
masks, redirect requests, distances, and the top-R merged pools must be
identical — not just close.  (The oracle is jitted for the comparison
because XLA:CPU's jitted reduction codegen differs from eager dispatch by
~1e-7 for some D — a jit-vs-eager artifact, not a kernel-vs-oracle one;
the production pipeline always runs jitted.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grnnd, pools
from repro.data import synthetic
from repro.kernels import ops, ref
from repro.kernels.rng_round import rng_round_pallas

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity



def _pool_and_pairs(seed, n, d, r, p, s=None):
    x = synthetic.vector_dataset(jax.random.PRNGKey(seed), n, d,
                                 n_clusters=max(2, n // 16))
    pool = pools.init_random(jax.random.PRNGKey(seed + 1), x,
                             s=s or min(6, r), r=r)
    ki, kj = jax.random.split(jax.random.PRNGKey(seed + 2))
    si = jax.random.randint(ki, (n, p), 0, r, jnp.int32)
    sj = jax.random.randint(kj, (n, p), 0, r, jnp.int32)
    return x, pool, si, sj


def _assert_round_parity(x, pool, si, sj):
    got = rng_round_pallas(x, pool.ids, pool.dists, si, sj, interpret=True)
    want = jax.jit(ref.rng_round_ref)(x, pool.ids, pool.dists, si, sj)
    for name, g, w in zip(("dst", "src", "dij", "kill"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
    return got


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_round_outputs_match_oracle_across_seeds(seed):
    x, pool, si, sj = _pool_and_pairs(seed, n=48, d=16, r=8, p=8)
    _assert_round_parity(x, pool, si, sj)


@pytest.mark.parametrize("n,d,r,p", [
    (50, 33, 12, 16),   # D not lane-aligned, R not a power of two
    (40, 130, 7, 5),    # D just past one lane tile, odd R/P
    (30, 16, 1, 3),     # R = 1: no valid pair can ever hit
    (16, 8, 8, 1),      # single sampled pair per vertex
])
def test_round_edge_shapes(n, d, r, p):
    x, pool, si, sj = _pool_and_pairs(7, n=n, d=d, r=r, p=p)
    dst, _, _, kill = _assert_round_parity(x, pool, si, sj)
    if r == 1:
        assert not bool(jnp.any(kill))
        assert bool(jnp.all(dst == -1))


def test_round_empty_pool_is_inert():
    x = synthetic.vector_dataset(jax.random.PRNGKey(9), 20, 8, n_clusters=2)
    ep = pools.empty_pool(20, 6)
    si = jax.random.randint(jax.random.PRNGKey(1), (20, 4), 0, 6, jnp.int32)
    sj = jax.random.randint(jax.random.PRNGKey(2), (20, 4), 0, 6, jnp.int32)
    dst, _, _, kill = _assert_round_parity(x, ep, si, sj)
    assert bool(jnp.all(dst == -1))
    assert not bool(jnp.any(kill))


def test_partially_filled_pool_kills_only_live_slots():
    """s < r leaves empty tail slots; kills must never land on them."""
    x, pool, si, sj = _pool_and_pairs(11, n=64, d=12, r=16, p=16, s=4)
    _, _, _, kill = _assert_round_parity(x, pool, si, sj)
    assert not bool(jnp.any(jnp.asarray(kill) & (pool.ids < 0)))


@pytest.mark.parametrize("seed", [0, 3])
def test_merged_pools_identical_across_backends(seed):
    """End-to-end: update_round under the interpret backend must produce the
    SAME top-R merged pools as under the ref backend (sampling is shared, the
    distance math is bitwise-parallel, and the staging sort is common)."""
    x = synthetic.vector_dataset(jax.random.PRNGKey(seed), 96, 12,
                                 n_clusters=6)
    cfg = grnnd.GRNNDConfig(s=6, r=8, t1=1, t2=1, pairs_per_vertex=8)
    pool = pools.init_random(jax.random.PRNGKey(seed + 1), x, cfg.s, cfg.r)
    key = jax.random.PRNGKey(seed + 2)

    prev = ops.get_backend()
    try:
        ops.set_backend("ref")
        p_ref = jax.jit(grnnd.update_round, static_argnames="cfg")(
            x, pool, key, cfg)
        ops.set_backend("interpret")
        p_int = jax.jit(grnnd.update_round, static_argnames="cfg")(
            x, pool, key, cfg)
    finally:
        ops.set_backend(prev)

    np.testing.assert_array_equal(np.asarray(p_ref.ids), np.asarray(p_int.ids))
    np.testing.assert_array_equal(np.asarray(p_ref.dists),
                                  np.asarray(p_int.dists))


def test_chunked_round_matches_unchunked_matrices():
    """The lax.map chunked plan must reproduce the one-shot fused outputs."""
    x = synthetic.vector_dataset(jax.random.PRNGKey(5), 64, 8, n_clusters=4)
    cfg = grnnd.GRNNDConfig(s=6, r=8, t1=1, t2=1, pairs_per_vertex=6)
    pool = pools.init_random(jax.random.PRNGKey(6), x, cfg.s, cfg.r)
    key = jax.random.PRNGKey(7)
    # chunking changes the key->pair mapping (keys are split per chunk), so
    # compare each chunk against a direct call with the same chunk key
    cfg_c = cfg._replace(chunk_size=16)
    dst, src, dij, kill = grnnd._round_pair_matrices(x, pool, key, cfg_c)
    keys = jax.random.split(key, 64 // 16)
    for i in range(4):
        sl = slice(16 * i, 16 * (i + 1))
        want = grnnd._pair_matrices_chunk(
            x, pool.ids[sl], pool.dists[sl], keys[i], cfg_c)
        np.testing.assert_array_equal(np.asarray(dst[sl]), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(kill[sl]),
                                      np.asarray(want[3]))


def test_env_var_selects_backend(monkeypatch):
    """REPRO_KERNEL_BACKEND is honored at import time; 'xla' aliases 'ref'."""
    assert ops._normalize("xla") == "ref"
    assert ops._normalize("pallas") == "pallas"
    with pytest.raises(AssertionError):
        ops._normalize("cuda")
