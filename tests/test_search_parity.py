"""Parity suite for the production query path (DESIGN.md §6).

Three contracts:

  * hashed-visited search is BITWISE identical to the dense-visited
    reference whenever `visited_cap >= N` — identity-mod hashing is
    injective there, so no collisions and no capacity misses exist;
  * at realistic caps (the `default_visited_cap` serving configuration)
    recall matches the dense baseline to within 1e-3 — collisions only
    cause harmless re-expansions, never false skips;
  * the fused `search_expand` kernel (interpret mode) matches the ref.py
    oracle bitwise, per the same common-jit-context convention as
    tests/test_rng_round.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grnnd, recall
from repro.core.search import _table_insert, search
from repro.data import synthetic
from repro.kernels import ops, ref
from repro.kernels.search_expand import search_expand_pallas
from conftest import optional_hypothesis

# every suite in the interpret CI leg carries this marker: the
# matrix selects `-m kernel_parity` instead of a hand-kept file list
pytestmark = pytest.mark.kernel_parity


given, settings, st = optional_hypothesis()


@pytest.fixture(scope="module")
def built():
    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", 900)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, 96)
    gt = recall.brute_force_knn(x, q, 10)
    cfg = grnnd.GRNNDConfig(s=8, r=16, t1=3, t2=3, pairs_per_vertex=16)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x, cfg)
    return x, pool.ids, q, gt


# ---------------------------------------------------------------------------
# hashed visited set vs the dense reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ef", [16, 48])
def test_hashed_bitwise_identical_at_full_cap(built, ef):
    """visited_cap >= N: zero collisions -> the exact same trajectory."""
    x, ids, q, _ = built
    d = search(x, ids, q, k=10, ef=ef, visited="dense")
    h = search(x, ids, q, k=10, ef=ef, visited="hashed",
               visited_cap=x.shape[0])
    np.testing.assert_array_equal(np.asarray(d.ids), np.asarray(h.ids))
    np.testing.assert_array_equal(np.asarray(d.dists), np.asarray(h.dists))
    np.testing.assert_array_equal(np.asarray(d.n_expanded),
                                  np.asarray(h.n_expanded))


def test_hashed_recall_at_realistic_cap(built):
    """Default serving cap (O(ef), independent of N): recall within 1e-3."""
    x, ids, q, gt = built
    r_d = recall.recall_at_k(
        search(x, ids, q, k=10, ef=48, visited="dense").ids, gt)
    r_h = recall.recall_at_k(
        search(x, ids, q, k=10, ef=48, visited="hashed").ids, gt)
    assert abs(r_d - r_h) <= 1e-3, (r_d, r_h)


def test_hashed_tiny_cap_still_correct_distances(built):
    """A deliberately undersized table (many capacity misses) may cost
    work, but every returned (id, dist) pair must still be exact."""
    x, ids, q, _ = built
    res = search(x, ids, q[:8], k=5, ef=16, visited="hashed", visited_cap=32)
    r_ids, r_d = np.asarray(res.ids), np.asarray(res.dists)
    xs, qs = np.asarray(x), np.asarray(q[:8])
    for qi in range(8):
        row = r_ids[qi][r_ids[qi] >= 0]
        assert len(row) == len(set(row.tolist()))  # merge dedup held
        for slot, v in enumerate(r_ids[qi]):
            if v < 0:
                continue
            want = float(((qs[qi] - xs[v]) ** 2).sum())
            np.testing.assert_allclose(r_d[qi, slot], want, rtol=1e-4,
                                       atol=1e-5)


def _check_saturated_cap(built, cap, ef, qseed):
    """The visited-table SATURATION contract (DESIGN.md §6.1): when
    `visited_cap` is forced far below the true visited count, capacity
    misses flood the probe path — yet the search must still terminate
    (the beam's own dedup-and-expanded bookkeeping bounds the walk, not
    the table), return exact deduped (id, dist) pairs, and hold recall
    within 0.05 of the dense baseline (the documented degraded-recall
    floor; empirically the loss is ~0 — saturation costs re-expansion
    WORK, visible as an inflated n_expanded, not correctness)."""
    x, ids, q, _ = built
    q = synthetic.queries_from(jax.random.PRNGKey(qseed), x, 32)
    gt = recall.brute_force_knn(x, q, 10)
    d = search(x, ids, q, k=10, ef=ef, visited="dense")
    h = search(x, ids, q, k=10, ef=ef, visited="hashed", visited_cap=cap)
    # the table is saturated: far more fresh sightings than it can store
    assert float(jnp.sum(h.n_expanded)) > float(jnp.sum(d.n_expanded))
    r_ids, r_d = np.asarray(h.ids), np.asarray(h.dists)
    xs, qs = np.asarray(x), np.asarray(q)
    for qi in range(q.shape[0]):
        row = r_ids[qi][r_ids[qi] >= 0]
        assert len(row) == len(set(row.tolist()))     # merge dedup held
        for slot, v in enumerate(r_ids[qi]):
            if v >= 0:
                want = float(((qs[qi] - xs[v]) ** 2).sum())
                np.testing.assert_allclose(r_d[qi, slot], want, rtol=1e-4,
                                           atol=1e-5)
    r_dense = recall.recall_at_k(d.ids, gt)
    r_hash = recall.recall_at_k(h.ids, gt)
    assert r_hash >= r_dense - 0.05, (cap, ef, r_dense, r_hash)


@pytest.mark.parametrize("cap,ef", [(1, 16), (8, 48), (24, 48)])
def test_saturated_cap_terminates_and_holds_recall_floor(built, cap, ef):
    _check_saturated_cap(built, cap, ef, qseed=77)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_saturated_cap_property(built, data):
    """Hypothesis sweep of (cap, ef, query draw) deep inside saturation:
    no table size may break termination, exactness, or the recall floor."""
    cap = data.draw(st.integers(1, 64))
    ef = data.draw(st.sampled_from([16, 48]))
    qseed = data.draw(st.integers(0, 2**16))
    _check_saturated_cap(built, cap, ef, qseed)


def test_table_insert_then_probe_roundtrip():
    """Inserted ids are found; non-inserted ids are not (no false
    positives even under heavy collision load)."""
    key = jax.random.PRNGKey(3)
    ids = jax.random.randint(key, (4, 12), -1, 200)
    tab = _table_insert(jnp.full((4, 64), -1, jnp.int32), ids)
    pos = ref.visited_probe_positions(ids, 64)
    vals = np.asarray(tab)[np.arange(4)[:, None, None], np.asarray(pos)]
    found = np.any(vals == np.asarray(ids)[..., None], axis=-1)
    table_np = np.asarray(tab)
    for qi in range(4):
        stored = set(table_np[qi][table_np[qi] >= 0].tolist())
        for v, f in zip(np.asarray(ids)[qi], found[qi]):
            if v < 0:
                continue
            # found <-> actually stored (misses are allowed, lies are not)
            assert f == (int(v) in stored)


# ---------------------------------------------------------------------------
# fused expand kernel vs oracle
# ---------------------------------------------------------------------------

def _expand_case(seed, qn, r, n, d, h, fill):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    x = synthetic.vector_dataset(k1, n, d, n_clusters=max(2, n // 16))
    q = synthetic.queries_from(k2, x, qn)
    nbrs = jax.random.randint(k3, (qn, r), -1, n)
    tab = jnp.full((qn, h), -1, jnp.int32)
    if fill:  # insert half the neighbor ids so probes hit and miss
        tab = _table_insert(tab, jnp.where(
            jax.random.bernoulli(k4, 0.5, (qn, r)), nbrs, -1))
    return x, q, nbrs, tab


@pytest.mark.parametrize("qn,r,n,d,h,fill", [
    (8, 10, 64, 12, 32, True),
    (5, 7, 50, 33, 16, True),    # D not lane-aligned, odd shapes
    (4, 8, 40, 16, 1, False),    # H = 1: the dense-path dummy table
    (3, 6, 30, 8, 3, True),      # H < PROBES: multi-wrap probe windows
    (3, 6, 30, 8, 256, True),    # sparse table, wide H
])
def test_expand_matches_oracle(qn, r, n, d, h, fill):
    x, q, nbrs, tab = _expand_case(11, qn, r, n, d, h, fill)
    got = search_expand_pallas(x, q, nbrs, tab, interpret=True)
    want = jax.jit(ref.search_expand_ref)(x, q, nbrs, tab)
    for name, g, w in zip(("ids", "dists", "fresh"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_expand_all_invalid_rows_inert():
    x, q, _, tab = _expand_case(13, 4, 6, 32, 8, 16, False)
    nbrs = jnp.full((4, 6), -1, jnp.int32)
    ids, d, fresh = search_expand_pallas(x, q, nbrs, tab, interpret=True)
    assert bool(jnp.all(ids == -1))
    assert bool(jnp.all(jnp.isinf(d)))
    assert not bool(jnp.any(fresh))


def test_search_backend_parity_end_to_end(built):
    """Interpret-backend search (fused kernels) == ref-backend search,
    bitwise, for both visited representations."""
    x, ids, q, _ = built
    for visited in ("dense", "hashed"):
        with ops.backend("ref"):
            a = search(x, ids, q[:16], k=5, ef=16, visited=visited)
        with ops.backend("interpret"):
            b = search(x, ids, q[:16], k=5, ef=16, visited=visited)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids),
                                      err_msg=visited)
        np.testing.assert_array_equal(np.asarray(a.dists),
                                      np.asarray(b.dists), err_msg=visited)
