"""Serving engine + kNN-LM retrieval integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Engine decode loops (~13 s) — nightly tier.
pytestmark = pytest.mark.slow

from repro.configs import get_arch, reduced
from repro.core.grnnd import GRNNDConfig
from repro.models import transformer as T
from repro.retrieval import knn_lm
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(get_arch("gemma3-1b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestServeEngine:
    def test_greedy_generation_deterministic(self, tiny_model):
        cfg, params = tiny_model
        eng = ServeEngine(cfg, params, s_max=48, act_dtype=jnp.float32)
        batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None].repeat(2, 0)}
        out1 = eng.generate(batch, max_new_tokens=8)
        out2 = eng.generate(batch, max_new_tokens=8)
        np.testing.assert_array_equal(out1["tokens"], out2["tokens"])
        assert out1["tokens"].shape == (2, 8)
        assert bool(jnp.all(out1["final_pos"] == 16 + 8))

    def test_greedy_matches_manual_decode(self, tiny_model):
        """Engine's first generated token == argmax of prefill logits."""
        cfg, params = tiny_model
        eng = ServeEngine(cfg, params, s_max=32, act_dtype=jnp.float32)
        batch = {"tokens": jnp.arange(12, dtype=jnp.int32)[None]}
        out = eng.generate(batch, max_new_tokens=1)
        logits, _, _ = T.prefill(params, cfg, batch, s_max=32,
                                 act_dtype=jnp.float32)
        assert int(out["tokens"][0, 0]) == int(jnp.argmax(logits[0]))

    def test_sampled_generation_runs(self, tiny_model):
        cfg, params = tiny_model
        eng = ServeEngine(cfg, params, s_max=32, act_dtype=jnp.float32)
        batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
        out = eng.generate(batch, max_new_tokens=4, temperature=1.0,
                           key=jax.random.PRNGKey(5))
        assert out["tokens"].shape == (1, 4)
        assert bool(jnp.all(out["tokens"] >= 0))
        assert bool(jnp.all(out["tokens"] < cfg.vocab))


class TestKnnLM:
    def test_datastore_and_fusion_memorizes(self):
        """Retrieval must recover memorized (key -> token) pairs."""
        key = jax.random.PRNGKey(1)
        n, d, vocab = 600, 16, 50
        keys_h = jax.random.normal(key, (n, d))
        vals = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, vocab)
        store = knn_lm.build_datastore(
            jax.random.PRNGKey(3), keys_h, vals,
            GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16))

        # query AT the stored keys: top-1 neighbor is the key itself
        q = keys_h[:64]
        klp = knn_lm.knn_logits(store, q, vocab, k=4, ef=24)
        pred = jnp.argmax(klp, axis=-1)
        acc = float(jnp.mean((pred == vals[:64]).astype(jnp.float32)))
        assert acc > 0.9, acc

    def test_fuse_is_valid_distribution(self):
        lm = jax.random.normal(jax.random.PRNGKey(4), (5, 30))
        knn = jax.nn.log_softmax(
            jax.random.normal(jax.random.PRNGKey(5), (5, 30)))
        fused = knn_lm.fuse(lm, knn, lam=0.3)
        total = jnp.exp(jax.nn.logsumexp(fused, axis=-1))
        np.testing.assert_allclose(total, np.ones(5), rtol=1e-5)

    def test_lam_zero_is_pure_lm(self):
        lm = jax.random.normal(jax.random.PRNGKey(6), (3, 20))
        knn = jnp.full((3, 20), -1e9)
        fused = knn_lm.fuse(lm, knn, lam=1e-9)
        np.testing.assert_allclose(fused, jax.nn.log_softmax(lm, -1),
                                   atol=1e-5)
