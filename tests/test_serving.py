"""Serving engine + kNN-LM retrieval integration tests, plus the ANN
launch-CLI end-to-end smoke: build_index -> serve over a real subprocess
boundary (the artifact format, the CLI flags, and the printed metrics are
all part of the served contract)."""
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Engine decode loops + CLI subprocesses (~13 s + ~20 s) — nightly tier.
pytestmark = pytest.mark.slow

from repro.configs import get_arch, reduced
from repro.core.grnnd import GRNNDConfig
from repro.models import transformer as T
from repro.retrieval import knn_lm
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(get_arch("gemma3-1b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestServeEngine:
    def test_greedy_generation_deterministic(self, tiny_model):
        cfg, params = tiny_model
        eng = ServeEngine(cfg, params, s_max=48, act_dtype=jnp.float32)
        batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None].repeat(2, 0)}
        out1 = eng.generate(batch, max_new_tokens=8)
        out2 = eng.generate(batch, max_new_tokens=8)
        np.testing.assert_array_equal(out1["tokens"], out2["tokens"])
        assert out1["tokens"].shape == (2, 8)
        assert bool(jnp.all(out1["final_pos"] == 16 + 8))

    def test_greedy_matches_manual_decode(self, tiny_model):
        """Engine's first generated token == argmax of prefill logits."""
        cfg, params = tiny_model
        eng = ServeEngine(cfg, params, s_max=32, act_dtype=jnp.float32)
        batch = {"tokens": jnp.arange(12, dtype=jnp.int32)[None]}
        out = eng.generate(batch, max_new_tokens=1)
        logits, _, _ = T.prefill(params, cfg, batch, s_max=32,
                                 act_dtype=jnp.float32)
        assert int(out["tokens"][0, 0]) == int(jnp.argmax(logits[0]))

    def test_sampled_generation_runs(self, tiny_model):
        cfg, params = tiny_model
        eng = ServeEngine(cfg, params, s_max=32, act_dtype=jnp.float32)
        batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
        out = eng.generate(batch, max_new_tokens=4, temperature=1.0,
                           key=jax.random.PRNGKey(5))
        assert out["tokens"].shape == (1, 4)
        assert bool(jnp.all(out["tokens"] >= 0))
        assert bool(jnp.all(out["tokens"] < cfg.vocab))


class TestServeCLI:
    """build_index.py -> serve.py --filter-labels over subprocesses: the
    ISSUE 5 end-to-end smoke.  Asserts the filtered-serving hard invariant
    (pred_ok == 1.0: every returned id satisfies its predicate) and that
    the reported recall field parses — against the tiny `sift-demo`
    dataset config (seconds-scale CPU build)."""

    @pytest.fixture(scope="class")
    def demo_index(self, tmp_path_factory):
        out = str(tmp_path_factory.mktemp("idx") / "demo.idx.npz")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.build_index",
             "--dataset", "sift-demo", "--out", out],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-3000:]
        assert os.path.exists(out)
        return out, env

    def _serve(self, demo_index, *extra):
        out, env = demo_index
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--index", out,
             "--batches", "2", "--batch-size", "48", "--ef", "32",
             "--backend", "ref", "--filter-labels", "20",
             "--selectivity", "0.2", *extra],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [ln for ln in proc.stdout.splitlines() if "qps=" in ln][-1]
        return line

    def test_filtered_serve_end_to_end(self, demo_index):
        line = self._serve(demo_index)
        assert "filtered=1" in line and "selectivity=0.2" in line
        # the hard invariant: 100% of returned ids satisfy their predicate
        pred = re.search(r"pred_ok=([\d.]+)", line)
        assert pred and float(pred.group(1)) == 1.0, line
        # the reported (filtered) recall field parses and is sane
        rec = re.search(r"recall@10=([\d.]+)", line)
        assert rec is not None, line
        assert 0.0 <= float(rec.group(1)) <= 1.0
        assert float(rec.group(1)) >= 0.9, line  # allowed-subset recall

    def test_filtered_serve_mutable_end_to_end(self, demo_index):
        """Labels ride the churn path: insert/delete under a predicate."""
        line = self._serve(demo_index, "--mutable", "--churn", "16")
        assert "filtered=1" in line and "mutable=1" in line
        pred = re.search(r"pred_ok=([\d.]+)", line)
        assert pred and float(pred.group(1)) == 1.0, line
        rec = re.search(r"recall@10=([\d.]+)", line)
        assert rec and 0.0 <= float(rec.group(1)) <= 1.0, line

    def test_corpus_sharded_serve_end_to_end(self, demo_index):
        """ISSUE 7 e2e: `--corpus-shards 2` over a real subprocess with two
        forced host devices — the stats line must carry the schema-5
        `corpus_shards=` field and the sharded recall must clear the same
        bar the replicated serve does (the search is bitwise-identical,
        so any gap would be an artifact-format or wiring bug)."""
        out, env = demo_index
        env2 = dict(env)
        env2["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--index", out,
             "--batches", "2", "--batch-size", "48", "--ef", "32",
             "--backend", "ref", "--corpus-shards", "2"],
            env=env2, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [ln for ln in proc.stdout.splitlines() if "qps=" in ln][-1]
        assert "corpus_shards=2" in line, line
        rec = re.search(r"recall@10=([\d.]+)", line)
        assert rec is not None, line
        assert float(rec.group(1)) >= 0.85, line
        # validated by the benchmarks/run.py schema-5 field contract
        from benchmarks.run import _CS_RE
        m = _CS_RE.search(line)
        assert m and int(m.group(1)) == 2, line

    def test_corpus_shards_with_query_shards_is_rejected(self, demo_index):
        out, env = demo_index
        env2 = dict(env)
        env2["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--index", out,
             "--corpus-shards", "2", "--shards", "2"],
            env=env2, capture_output=True, text=True, timeout=120)
        assert proc.returncode != 0
        assert "--corpus-shards" in proc.stderr

    def test_selectivity_without_filter_is_rejected(self, demo_index):
        out, env = demo_index
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--index", out,
             "--selectivity", "0.2"],
            env=env, capture_output=True, text=True, timeout=120)
        assert proc.returncode != 0
        assert "--selectivity" in proc.stderr


class TestKnnLM:
    def test_datastore_and_fusion_memorizes(self):
        """Retrieval must recover memorized (key -> token) pairs."""
        key = jax.random.PRNGKey(1)
        n, d, vocab = 600, 16, 50
        keys_h = jax.random.normal(key, (n, d))
        vals = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, vocab)
        store = knn_lm.build_datastore(
            jax.random.PRNGKey(3), keys_h, vals,
            GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16))

        # query AT the stored keys: top-1 neighbor is the key itself
        q = keys_h[:64]
        klp = knn_lm.knn_logits(store, q, vocab, k=4, ef=24)
        pred = jnp.argmax(klp, axis=-1)
        acc = float(jnp.mean((pred == vals[:64]).astype(jnp.float32)))
        assert acc > 0.9, acc

    def test_fuse_is_valid_distribution(self):
        """Mass exactly 1 at a REAL vocab size, with sparse -inf support
        rows — the seed's log(1e-9) clamp leaked ~lam*vocab*1e-9 of mass,
        invisible at vocab 30 and material at 50k (DESIGN.md §14)."""
        vocab = 50_000
        lm = jax.random.normal(jax.random.PRNGKey(4), (5, vocab))
        # realistic vote: a handful of supported tokens, all else -inf
        knn = jnp.full((5, vocab), -jnp.inf)
        knn = knn.at[:, :7].set(jax.nn.log_softmax(
            jax.random.normal(jax.random.PRNGKey(5), (5, 7))))
        fused = knn_lm.fuse(lm, knn, lam=0.3)
        total = jnp.exp(jax.nn.logsumexp(fused, axis=-1))
        np.testing.assert_allclose(total, np.ones(5), rtol=1e-6)

    def test_lam_zero_is_pure_lm(self):
        lm = jax.random.normal(jax.random.PRNGKey(6), (3, 20))
        knn = jnp.full((3, 20), -1e9)
        fused = knn_lm.fuse(lm, knn, lam=1e-9)
        np.testing.assert_allclose(fused, jax.nn.log_softmax(lm, -1),
                                   atol=1e-5)
