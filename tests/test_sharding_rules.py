"""Sharding-rule unit tests: divisibility fallbacks, policy selection,
cache layouts — pure spec logic, no device mesh needed beyond a stub."""
import jax
import pytest
from jax.sharding import PartitionSpec as PSpec

from repro.configs import get_arch
from repro.configs.base import SHAPES


@pytest.fixture(scope="module")
def mesh16():
    # a (4, 4) stand-in mesh with the production axis names
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single CPU device replicated into an abstract mesh is not allowed;
    # use AbstractMesh for pure spec logic
    from repro.compat import abstract_mesh
    return abstract_mesh((4, 4), ("data", "model"))


class TestParamSpecs:
    def _spec(self, mesh, name, shape, stacked=False):
        from repro.distributed.sharding import _param_spec
        return _param_spec(name, shape, mesh, stacked)

    def test_attention_heads_shard_when_divisible(self, mesh16):
        s = self._spec(mesh16, "attn/wq", (1024, 8, 128))
        assert s == PSpec(None, "model", None)

    def test_small_head_count_falls_back_to_head_dim(self, mesh16):
        # 2 heads cannot shard over 4-way model; Dh=128 can
        s = self._spec(mesh16, "attn/wq", (1024, 2, 128))
        assert s == PSpec(None, None, "model")

    def test_single_kv_head_falls_back(self, mesh16):
        s = self._spec(mesh16, "attn/wk", (1152, 1, 256))
        assert s == PSpec(None, None, "model")

    def test_stacked_leading_axis_never_sharded(self, mesh16):
        s = self._spec(mesh16, "segments/0/attn/wq", (24, 1024, 8, 128),
                       stacked=True)
        assert s[0] is None
        assert "model" in tuple(s)

    def test_norms_replicate(self, mesh16):
        s = self._spec(mesh16, "ln1", (1024,))
        assert s == PSpec(None)

    def test_experts_shard_over_model(self, mesh16):
        s = self._spec(mesh16, "moe/wi_gate", (64, 2048, 1408))
        assert s == PSpec("model", None, None)

    def test_vocab_shards(self, mesh16):
        s = self._spec(mesh16, "embed", (256000, 2304))
        assert s == PSpec("model", None)

    def test_fsdp_extends_over_data(self, mesh16):
        from repro.distributed.sharding import _extend_fsdp
        base = PSpec("model", None)
        s = _extend_fsdp(base, (256000, 2304), mesh16, stacked=False)
        assert s == PSpec("model", ("data",))


class TestPolicy:
    def _policy(self, arch, shape="train_4k"):
        # policy only reads mesh.shape; fake it
        class FakeMesh:
            shape = {"data": 16, "model": 16}
        from repro.launch.specs import parallelism_policy
        return parallelism_policy(get_arch(arch), SHAPES[shape], FakeMesh())

    def test_tiny_model_dp_only(self):
        assert self._policy("mamba2-130m") == "dp_only"

    def test_mid_model_tp(self):
        assert self._policy("gemma2-2b") == "tp"

    def test_27b_zero1(self):
        assert self._policy("gemma3-27b") == "zero1"

    def test_235b_fsdp(self):
        assert self._policy("qwen3-moe-235b-a22b") == "fsdp"

    def test_dp_only_requires_divisible_batch(self):
        # decode batch 128 is not divisible by 256 chips -> not dp_only
        assert self._policy("mamba2-130m", "decode_32k") in ("tp",)
