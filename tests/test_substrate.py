"""Substrate tests: optimizer, checkpoint, compression, fault tolerance,
data pipeline, training-loop integration (loss decreases)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# Training-loop/checkpoint integration (~30 s) — nightly tier.
pytestmark = pytest.mark.slow

from repro.checkpoint import checkpoint as CKPT
from repro.configs import get_arch, reduced
from repro.data import pipeline as PIPE
from repro.distributed import compression as COMP
from repro.distributed.fault_tolerance import (
    Coordinator, StragglerPolicy, TrainingSupervisor)
from repro.models import transformer as T
from repro.train import optimizer as O
from repro.train import train_step as TS


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0, 1.0])}
        opt_cfg = O.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200)
        state = O.init(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, _ = O.apply(opt_cfg, state, params, grads)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.1

    def test_clip_norm(self):
        params = {"w": jnp.zeros(3)}
        opt_cfg = O.AdamWConfig(clip_norm=1.0)
        state = O.init(params)
        _, _, m = O.apply(opt_cfg, state, params, {"w": jnp.full(3, 100.0)})
        assert float(m["grad_norm"]) > 100.0  # pre-clip norm reported

    def test_schedule_warmup_and_decay(self):
        cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
        assert float(O.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(O.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(O.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(6.0).reshape(2, 3) + k,
                "b": {"c": jnp.asarray(7 + k), "d": jnp.ones((4,)) * k}}

    def test_roundtrip(self, tmp_path):
        t = self._tree(3)
        CKPT.save(tmp_path, 12, t)
        got = CKPT.restore(tmp_path, 12, jax.eval_shape(lambda: t))
        jax.tree.map(np.testing.assert_array_equal, got, t)

    def test_latest_and_prune(self, tmp_path):
        for s in (1, 5, 9, 13):
            CKPT.save(tmp_path, s, self._tree(s))
        assert CKPT.latest_step(tmp_path) == 13
        CKPT.prune_old(tmp_path, keep=2)
        assert CKPT.latest_step(tmp_path) == 13
        with pytest.raises(FileNotFoundError):
            CKPT.restore(tmp_path, 1, jax.eval_shape(lambda: self._tree()))

    def test_atomic_commit_no_partial(self, tmp_path):
        # a .tmp dir must never be visible as a checkpoint
        CKPT.save(tmp_path, 2, self._tree())
        leftovers = [p for p in tmp_path.iterdir() if ".tmp" in p.name]
        assert not leftovers

    def test_elastic_restore_resharding(self, tmp_path):
        """Restore under a different device mapping (simulated elastic)."""
        t = {"w": jnp.arange(16.0).reshape(4, 4)}
        CKPT.save(tmp_path, 1, t)
        # restore with explicit (single-device) shardings
        from jax.sharding import SingleDeviceSharding
        sh = {"w": SingleDeviceSharding(jax.devices()[0])}
        got = CKPT.restore(tmp_path, 1, jax.eval_shape(lambda: t), sh)
        np.testing.assert_array_equal(got["w"], t["w"])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_quantize_roundtrip_accuracy(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s = COMP.quantize_int8(x, block=128)
        back = COMP.dequantize_int8(q, s, x.shape, block=128)
        # per-block max error is scale/2 = |max|/254
        assert float(jnp.max(jnp.abs(back - x))) < float(
            jnp.max(jnp.abs(x))) / 100.0

    def test_error_feedback_unbiased(self):
        """With error feedback, repeated compression of a constant gradient
        transmits the full value on average (residual stays bounded)."""
        g = {"w": jnp.asarray([0.001, -1.0, 0.5])}
        resid = COMP.ErrorFeedback.init(g)
        total = jnp.zeros(3)
        for _ in range(50):
            sent, resid = COMP.ErrorFeedback.compress(g, resid)
            total = total + sent["w"]
        np.testing.assert_allclose(total / 50, g["w"], atol=1e-3)

    def test_compressed_psum_matches_mean(self):
        import os
        import subprocess
        import sys
        import textwrap
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            import numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.compat import shard_map
            from repro.distributed.compression import compressed_psum_mean
            mesh = jax.make_mesh((4,), ("pod",))
            x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
            f = shard_map(
                lambda v: compressed_psum_mean(v[0], "pod")[None],
                mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
            got = np.asarray(f(x))
            want = np.asarray(jnp.mean(x, 0))
            for row in got:
                np.testing.assert_allclose(row, want, atol=0.05)
            print("OK")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]


# ---------------------------------------------------------------------------
# fault tolerance / elasticity / stragglers
# ---------------------------------------------------------------------------

class TestFaultTolerance:
    def test_failure_detection(self):
        clock = [0.0]
        c = Coordinator(4, heartbeat_timeout=5.0, now=lambda: clock[0])
        clock[0] = 4.0
        for h in (0, 1, 2):
            c.heartbeat(h)
        clock[0] = 7.0
        dead = c.check_failures()
        assert dead == [3]
        assert c.alive_hosts() == [0, 1, 2]

    def test_elastic_mesh_shrinks(self):
        clock = [0.0]
        c = Coordinator(8, heartbeat_timeout=1.0, now=lambda: clock[0])
        assert c.elastic_mesh_shape(chips_per_host=4, model_parallelism=4) \
            == (8, 4)
        clock[0] = 2.0
        c.heartbeat(0)
        c.heartbeat(1)
        c.heartbeat(2)
        c.check_failures()
        # 3 hosts * 4 chips = 12 chips; TP=4 -> data=3 -> pow2 -> 2
        assert c.elastic_mesh_shape(4, 4) == (2, 4)

    def test_straggler_deadline_skip(self):
        pol = StragglerPolicy(deadline_s=10.0, max_skip_frac=0.5)
        arrivals = {0: 1.0, 1: 2.0, 2: 50.0, 3: 3.0}
        keep, rescale = pol.select(arrivals)
        assert keep == [0, 1, 3]
        assert rescale == pytest.approx(4 / 3)

    def test_straggler_min_keep_floor(self):
        pol = StragglerPolicy(deadline_s=1.0, max_skip_frac=0.25)
        arrivals = {0: 5.0, 1: 9.0, 2: 2.0, 3: 7.0}
        keep, rescale = pol.select(arrivals)   # all late: keep fastest 3
        assert len(keep) == 3 and 2 in keep

    def test_supervisor_recovers_from_failure(self, tmp_path):
        """Kill a host mid-run; supervisor re-meshes + resumes from ckpt."""
        clock = [0.0]
        coord = Coordinator(4, heartbeat_timeout=5.0, now=lambda: clock[0])
        saved = {}

        def save_fn(state, step):
            saved[step] = state

        def restore_fn():
            step = max(saved)
            # all hosts healthy again after restart
            for h in coord.hosts.values():
                h.alive = True
                h.last_heartbeat = clock[0]
            return saved[step], step

        def step_fn(state, step):
            for h in coord.alive_hosts():
                coord.heartbeat(h)
            return state + 1

        def kill_host(c):
            c.hosts[2].last_heartbeat = -100.0

        sup = TrainingSupervisor(coord, save_every=5, save_fn=save_fn,
                                 restore_fn=restore_fn)
        state, step = sup.run(0, step_fn, n_steps=20,
                              events={12: lambda c: kill_host(c)})
        assert step == 20
        assert sup.restarts == 1
        # rollback to the step-10 checkpoint makes replayed work invisible
        # in the final state: exactly 20 effective increments
        assert state == 20


# ---------------------------------------------------------------------------
# data pipeline + end-to-end training
# ---------------------------------------------------------------------------

class TestTraining:
    def test_pipeline_deterministic_per_step(self):
        cfg = reduced(get_arch("gemma2-2b"))
        b1 = PIPE.batch_for_step(cfg, 7, 4, 32)
        b2 = PIPE.batch_for_step(cfg, 7, 4, 32)
        b3 = PIPE.batch_for_step(cfg, 8, 4, 32)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_loss_decreases_tiny_lm(self):
        from repro.launch.train import train
        _, hist = train("mamba2-130m", steps=60, batch=4, seq=64,
                        log_every=5, lr=3e-3)
        first, last = hist[0]["loss"], hist[-1]["loss"]
        assert last < first - 0.3, (first, last)

    def test_checkpoint_resume_bit_exact(self, tmp_path):
        from repro.launch.train import train
        # run 20 steps straight
        sA, _ = train("gemma3-1b", steps=20, batch=2, seq=32,
                      ckpt_dir=str(tmp_path / "a"), save_every=10)
        # preempt at 10, then resume to 20 (same 20-step schedule)
        train("gemma3-1b", steps=20, batch=2, seq=32, stop_at=10,
              ckpt_dir=str(tmp_path / "b"), save_every=10)
        sB, _ = train("gemma3-1b", steps=20, batch=2, seq=32,
                      ckpt_dir=str(tmp_path / "b"), save_every=10)
        a = jax.tree.leaves(sA.params)
        b = jax.tree.leaves(sB.params)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_microbatch_equivalence(self):
        """grad accumulation == single large batch (same loss trajectory)."""
        cfg = reduced(get_arch("h2o-danube-1.8b"))
        opt_cfg = O.AdamWConfig(lr=1e-3)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        batch = PIPE.batch_for_step(cfg, 0, 8, 32)

        s1 = TS.TrainState(params, O.init(params))
        s2 = TS.TrainState(params, O.init(params))
        f1 = jax.jit(TS.make_train_step(cfg, opt_cfg, microbatches=1,
                                        act_dtype=jnp.float32))
        f2 = jax.jit(TS.make_train_step(cfg, opt_cfg, microbatches=4,
                                        act_dtype=jnp.float32))
        s1, m1 = f1(s1, batch)
        s2, m2 = f2(s2, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=1e-4)
        for x, y in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5)
