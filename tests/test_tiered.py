"""Tiered-storage suite: the ISSUE 9 placement-invariance tier.

Tier placement (core/vecstore.py `HostTier`, DESIGN.md §13) moves the
fp32 rescore tier off the accelerator: traversal stays on the
device-resident quantized tier, and the post-beam re-rank becomes an
explicit cross-boundary gather — top-ef ids out, ef·D fp32 bytes back —
finished by the same jitted `_rescore_merge` formula the in-jit rescore
tail runs.  Placement must be INVISIBLE to the caller, and this suite
locks that as a bitwise claim:

  * **placement invariance** — host-cold search returns bitwise-identical
    ids, dists AND n_expanded to device-hot on every quantized rung,
    composed with filtering, hashed (small-cap, real-collision) visited
    sets, and the PR 6 optimized layout (ids_map applied AFTER the
    re-rank, same order as in-jit);
  * **every consumer** — replicated `search`, `CorpusShardedIndex`
    (S ∈ {1, 2} + the 1-device mesh executor), `distributed_search`
    (incl. the filtered pre-widened path), `DynamicIndex` through
    insert/delete churn, and the batching engine's `StaticWorker`;
  * **the memory claim** — `memory_report` attributes ZERO device bytes
    to a host-placed rescore tier (the N-ceiling lift fig15 measures),
    with the replicated-entry keys unchanged;
  * **the satellite regressions** — the pad-slot gather mask (no fp32
    row crosses the boundary for a -1 slot), the cached-entry delete
    invalidation interplay, and the empty-corpus quantizer path growing
    into a searchable host-tier index.

Runs in BOTH CI legs (REPRO_KERNEL_BACKEND=ref and =interpret) via the
`kernel_parity` marker.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus_shard as CS
from repro.core import grnnd, labels as L, layout as LY
from repro.core import vecstore as VS
from repro.core.dynamic import DynamicConfig, DynamicIndex
from repro.core.search import medoid, search

pytestmark = pytest.mark.kernel_parity

K = 10
EF = 32
N = 260
NQ = 12
CFG = grnnd.GRNNDConfig(s=8, r=16, t1=2, t2=3, pairs_per_vertex=16)
QUANTIZED = tuple(p for p in VS.PRECISIONS if p != "fp32")


@pytest.fixture(scope="module")
def case():
    from repro.data import synthetic
    x = synthetic.make_preset(jax.random.PRNGKey(0), "tiny", N)
    q = synthetic.queries_from(jax.random.PRNGKey(1), x, NQ)
    pool = grnnd.build_graph(jax.random.PRNGKey(2), x, CFG)
    return x, q, pool


def _assert_same(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids),
                                  err_msg=f"{msg}/ids")
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists),
                                  err_msg=f"{msg}/dists")
    np.testing.assert_array_equal(np.asarray(a.n_expanded),
                                  np.asarray(b.n_expanded),
                                  err_msg=f"{msg}/n_expanded")


# ---------------------------------------------------------------------------
# the HostTier object itself
# ---------------------------------------------------------------------------

def test_host_tier_placement_and_accounting(case):
    """The pinned tier lives on the CPU backend, reports zero device
    bytes and full host bytes, and dequantizes through the SAME formula
    as the in-jit rescore path (the parity precondition)."""
    x, _, _ = case
    vs = VS.encode(x, "int8")
    ht = VS.HostTier(vs)
    assert ht.data.devices() == {VS.host_device()}
    assert ht.shape == (N, x.shape[1]) and ht.n == N
    assert ht.device_bytes() == 0
    assert ht.host_bytes() == N * x.shape[1] * 4
    np.testing.assert_array_equal(np.asarray(ht.data),
                                  np.asarray(VS.dequant(vs)))
    assert VS.is_host(ht) and not VS.is_host(x) and not VS.is_host(vs)


def test_host_tier_gather_masks_pad_slots(case):
    """The satellite-3 regression: a -1 pad slot must contribute ZERO
    bytes to the cross-boundary transfer — not row 0's D floats, which
    the in-jit path's `clip(ids, 0)` harmlessly gathers on-device but a
    host tier would ship across the boundary.  Pad rows come back
    all-zero and `fetched_rows` counts only real rows."""
    x, _, _ = case
    ht = VS.HostTier(x)
    ids = jnp.asarray([[3, -1, 7], [-1, -1, 0]], jnp.int32)
    out = np.asarray(ht.gather(ids))
    assert out.shape == (2, 3, x.shape[1])
    xn = np.asarray(x)
    np.testing.assert_array_equal(out[0, 0], xn[3])
    np.testing.assert_array_equal(out[0, 2], xn[7])
    np.testing.assert_array_equal(out[1, 2], xn[0])
    assert not out[0, 1].any() and not out[1, 0].any() and not out[1, 1].any()
    assert ht.fetched_rows == 3  # -1 slots never cross the boundary


# ---------------------------------------------------------------------------
# placement invariance: host-cold == device-hot, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", QUANTIZED)
def test_host_tier_search_bitwise_equal(case, precision):
    """The acceptance core: moving the fp32 tier off-device changes
    NOTHING the caller can observe, on every quantized rung."""
    x, q, pool = case
    vs = VS.encode(x, precision)
    dev = search(vs, pool.ids, q, k=K, ef=EF, rescore=x)
    host = search(vs, pool.ids, q, k=K, ef=EF, rescore=VS.HostTier(x))
    _assert_same(dev, host, precision)


def test_host_tier_filtered_bitwise_equal(case):
    """Filtered search: route-through masking happens in the traversal
    tier; the predicate never touches the rescore placement."""
    x, q, pool = case
    vs = VS.encode(x, "int8")
    store = L.encode_labels(
        jax.random.randint(jax.random.PRNGKey(3), (N,), 0, 20), 20)
    fw = L.random_query_filters(jax.random.PRNGKey(4), NQ, 20, 0.25)
    dev = search(vs, pool.ids, q, k=K, ef=EF, rescore=x,
                 labels=store, filter=fw)
    host = search(vs, pool.ids, q, k=K, ef=EF, rescore=VS.HostTier(x),
                  labels=store, filter=fw)
    _assert_same(dev, host, "filtered")
    assert L.predicate_fraction(host.ids, fw, store.words) == 1.0


def test_host_tier_hashed_visited_bitwise_equal(case):
    """A small-cap hashed visited set with real collisions changes which
    candidates reach the final ef — both placements must re-rank the
    same candidate set identically."""
    x, q, pool = case
    vs = VS.encode(x, "bf16")
    dev = search(vs, pool.ids, q, k=K, ef=EF, rescore=x,
                 visited="hashed", visited_cap=64)
    host = search(vs, pool.ids, q, k=K, ef=EF, rescore=VS.HostTier(x),
                  visited="hashed", visited_cap=64)
    _assert_same(dev, host, "hashed")


def test_host_tier_layout_optimized_bitwise_equal(case):
    """The PR 6 composition: under an optimized layout the host re-rank
    runs in PERMUTED id space and the inverse map is applied after the
    k-slice — the same order as in-jit — so original-numbering results
    stay bitwise equal."""
    x, q, pool = case
    vs = VS.encode(x, "int8")
    opt = LY.optimize(vs, pool, order="hub", rescore=x)
    dev = opt.search(q, k=K, ef=EF)
    host = opt._replace(rescore=VS.HostTier(opt.rescore)).search(q, k=K, ef=EF)
    _assert_same(dev, host, "layout")


# ---------------------------------------------------------------------------
# corpus-sharded + distributed consumers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2])
def test_corpus_sharded_host_tier_bitwise_equal(case, n_shards):
    """`shard(tier='host')` keeps one UNSTACKED host tier indexed by
    global id; the post-combine re-rank (flat ids_map fold) is bitwise
    the owner-sliced on-device rescore — and bitwise the replicated
    search, transitively."""
    x, q, pool = case
    vs = VS.encode(x, "int8")
    dev = CS.shard(vs, pool.ids, n_shards, rescore=x)
    host = CS.shard(vs, pool.ids, n_shards, rescore=x, tier="host")
    assert VS.is_host(host.rescores)
    got = host.search(q, k=K, ef=EF)
    _assert_same(dev.search(q, k=K, ef=EF), got, f"S{n_shards}")
    _assert_same(search(vs, pool.ids, q, k=K, ef=EF, rescore=x), got,
                 f"S{n_shards}-vs-replicated")


def test_corpus_sharded_host_tier_mesh_executor(case):
    """The shard_map executor never sees the host tier (it is stripped
    before the mesh dispatch); the host re-rank applies after the
    owner-combine, bitwise the reference executor."""
    x, q, pool = case
    vs = VS.encode(x, "int8")
    mesh = jax.make_mesh((1,), ("corp",))
    host = CS.shard(vs, pool.ids, 1, rescore=x, tier="host")
    got = host.search(q, k=K, ef=EF, mesh=mesh, axes=("corp",))
    _assert_same(search(vs, pool.ids, q, k=K, ef=EF, rescore=x), got,
                 "mesh-host")


def test_corpus_sharded_host_tier_memory_report(case):
    """The N-ceiling lift: a host-placed rescore tier contributes ZERO
    device bytes per shard (vs N·D·4/S device-resident), the bytes
    reappear host-side, and the pre-existing report keys are unchanged
    by the placement axis."""
    x, _, pool = case
    vs = VS.encode(x, "int8")
    dev = CS.memory_report(CS.shard(vs, pool.ids, 2, rescore=x))
    host = CS.memory_report(CS.shard(vs, pool.ids, 2, rescore=x,
                                     tier="host"))
    assert dev["rescore_device_bytes"] > 0
    assert host["rescore_device_bytes"] == 0
    assert host["rescore_host_bytes"] == N * x.shape[1] * 4
    assert dev["rescore_host_bytes"] == 0
    assert host["per_shard_bytes"] < dev["per_shard_bytes"]
    # the lift shows up in BOTH layouts: exactly the fp32 tier's bytes
    # leave the replicated-per-device footprint too (N=260, S=2 divides
    # evenly, so the true-N fraction is 1 and the delta is exact)
    assert (dev["replicated_bytes"] - host["replicated_bytes"]
            == N * x.shape[1] * 4)


@pytest.mark.parametrize("filtered", [False, True])
def test_distributed_search_host_tier_bitwise_equal(case, filtered):
    """Query-sharded mesh search under the host tier: shards traverse
    WITHOUT the rescore operand (full-ef results, ids_map deferred) and
    the re-rank crosses the boundary once per batch.  The filtered leg
    exercises the pre-widened ef path (the inner search's overfetch is
    folded into ef_run so route-through refills are identical)."""
    from repro.core.distributed import distributed_search
    x, q, pool = case
    vs = VS.encode(x, "int8")
    mesh = jax.make_mesh((1,), ("q",))
    kw = {}
    if filtered:
        store = L.encode_labels(
            jax.random.randint(jax.random.PRNGKey(5), (N,), 0, 16), 16)
        kw = dict(labels=store,
                  filter=L.random_query_filters(jax.random.PRNGKey(6),
                                                NQ, 16, 0.3))
    dev = search(vs, pool.ids, q, k=K, ef=EF, rescore=x, **kw)
    got = distributed_search(mesh, ("q",), vs, pool.ids, q, k=K, ef=EF,
                             rescore=VS.HostTier(x), **kw)
    _assert_same(dev, got, f"dist/filtered={filtered}")


# ---------------------------------------------------------------------------
# DynamicIndex + engine consumers
# ---------------------------------------------------------------------------

def _dyn_pair(x, pool, **cfg_kw):
    dev = DynamicIndex(x, pool, DynamicConfig(precision="int8",
                                              refine_rounds=1, **cfg_kw))
    host = DynamicIndex(x, pool, DynamicConfig(precision="int8",
                                               refine_rounds=1,
                                               tier="host", **cfg_kw))
    return dev, host


def test_dynamic_host_tier_bitwise_through_churn(case):
    """A host-tier DynamicIndex answers bitwise like its device twin —
    at rest, after an insert batch (the cached HostTier is invalidated
    by the buffer swap), and after deletes — and its fp32 buffer stays
    committed to the CPU backend through the mutations."""
    x, q, pool = case
    dev, host = _dyn_pair(x, pool)
    assert host.x.devices() == {VS.host_device()}
    _assert_same(dev.search(q, k=K, ef=EF), host.search(q, k=K, ef=EF),
                 "rest")
    extra = np.asarray(jax.random.normal(jax.random.PRNGKey(7),
                                         (8, x.shape[1]), jnp.float32))
    dev.insert(extra)
    host.insert(extra)
    assert host.x.devices() == {VS.host_device()}
    _assert_same(dev.search(q, k=K, ef=EF), host.search(q, k=K, ef=EF),
                 "post-insert")
    dev.delete(np.arange(0, 40, 3))
    host.delete(np.arange(0, 40, 3))
    _assert_same(dev.search(q, k=K, ef=EF), host.search(q, k=K, ef=EF),
                 "post-delete")


def test_dynamic_host_tier_corpus_search(case):
    """`corpus_search` inherits the index's placement: the sharded path
    under tier='host' matches the index's own search in label space."""
    x, q, pool = case
    _, host = _dyn_pair(x, pool)
    base = host.search(q, k=K, ef=EF)
    for s in (1, 2):
        _assert_same(base, host.corpus_search(q, s, k=K, ef=EF),
                     f"dyn-corpus/S{s}")


def test_engine_static_worker_host_tier_bitwise(case):
    """The batching engine under the host tier: a StaticWorker handed a
    HostTier rescore answers every request bitwise like the direct
    host-tier search on the same batch shapes."""
    from repro.serve.ann_engine import AnnEngine, EngineConfig, StaticWorker
    x, q, pool = case
    vs = VS.encode(x, "int8")
    ht = VS.HostTier(x)
    entry = medoid(vs)
    worker = StaticWorker(vs, pool.ids, entry=entry, rescore=ht)
    eng = AnnEngine(worker, EngineConfig(ef_menu=(EF,), max_batch=8))
    qn = np.asarray(q)
    rids = [eng.submit(qn[i], k=K, ef=EF) for i in range(NQ)]
    eng.run()
    direct = search(vs, pool.ids, q, k=K, ef=EF, entry=entry, rescore=ht)
    for i, rid in enumerate(rids):
        res = eng.take_result(rid)
        np.testing.assert_array_equal(res.ids, np.asarray(direct.ids)[i])
        np.testing.assert_array_equal(res.dists,
                                      np.asarray(direct.dists)[i])


# ---------------------------------------------------------------------------
# satellite regressions: empty-corpus quantizer + host tier end to end
# ---------------------------------------------------------------------------

def test_empty_corpus_grows_into_searchable_host_index():
    """The satellite-2 integration: an EMPTY (0, D) int8 host-tier index
    constructs (quantizer freezes scale=1/offset=0 instead of crashing
    on the empty reduction) and grows into a searchable index whose
    results match its device twin bitwise."""
    from repro.core.pools import Pool
    d = 16
    empty = jnp.zeros((0, d), jnp.float32)
    pool0 = Pool(jnp.zeros((0, 8), jnp.int32), jnp.zeros((0, 8), jnp.float32))
    dev, host = _dyn_pair(empty, pool0)
    assert host.n_live == 0
    xs = np.asarray(jax.random.normal(jax.random.PRNGKey(8), (24, d),
                                      jnp.float32))
    dev.insert(xs)
    host.insert(xs)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (4, d),
                                     jnp.float32))
    res_d = dev.search(q, k=4, ef=8)
    res_h = host.search(q, k=4, ef=8)
    _assert_same(res_d, res_h, "empty-grow")
    assert np.asarray(res_h.ids)[:, 0].min() >= 0


@pytest.mark.parametrize("n", [0, 1])
def test_quantizer_edge_corpus_well_defined(n):
    """N ∈ {0, 1} quantization: finite scale/offset (no empty-reduction
    crash, no 0-range division), exact shapes, and a lossless N=1
    round-trip through the frozen affine map."""
    d = 8
    x = jnp.arange(n * d, dtype=jnp.float32).reshape(n, d)
    vs = VS.quantize_int8(x)
    assert vs.data.shape == (n, d) and vs.data.dtype == jnp.int8
    assert np.isfinite(np.asarray(vs.scale)).all()
    assert np.isfinite(np.asarray(vs.offset)).all()
    deq = np.asarray(VS.dequant(vs))
    assert deq.shape == (n, d)
    if n == 1:
        np.testing.assert_allclose(deq, np.asarray(x), atol=1e-5)
    ht = VS.HostTier(vs)  # and the host tier wraps the edge case too
    assert ht.host_bytes() == n * d * 4 and ht.device_bytes() == 0
